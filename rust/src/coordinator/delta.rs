//! The persistent, incrementally patched scoring problem behind every
//! coordinator decision.
//!
//! Pre-PR, each `place_arrival` / `remap_vm` / `reshuffle` / `interval`
//! call rebuilt the world from scratch: a sorted VM order, cloned
//! [`VmEntry`]s, a fresh [`ScoreProblem`] (including the O(V²) class-pair
//! matrix and the O(N²) padded distance matrix) and a fresh placement
//! matrix.  [`DeltaProblem`] holds all of that *persistently* and patches
//! only the rows the simulator's coordinator dirty set
//! ([`Simulator::drain_coord_dirty`]) names — O(dirty) per decision
//! instead of O(V·N + V²).
//!
//! Two complementary representations are maintained:
//!
//! * **Dense** (artifact-compatible systems: nodes ≤ compiled `num_nodes`
//!   and VMs ≤ compiled `max_vms`): the actual padded [`ScoreProblem`]
//!   plus the cached placement matrix, with rows kept sorted by [`VmId`]
//!   exactly like the rebuilt path's `vm_order` — the patched matrices are
//!   *bit-identical* to a fresh [`ScoreProblem::build`], so scorer results
//!   (PJRT or native) and therefore decisions are unchanged
//!   (property-tested).
//! * **Sparse aggregates** (always maintained; the only representation
//!   once the system outgrows the artifact shapes): per-node core load,
//!   memory-bandwidth load and per-(node, class) placement mass.  They
//!   power [`DeltaProblem::contribution`] — an O(|p|) per-candidate *delta* score
//!   whose candidate ordering equals the full scorer's (the rest of the
//!   system contributes a constant), which is what makes mapper decisions
//!   tractable at the ROADMAP's 100-server scale where a full [V,N]
//!   batch score would cost O(V²·N) per candidate.
//!
//! Mode policy: dense whenever the system fits the compiled shapes,
//! sparse-only while it does not.  A population that temporarily
//! outgrows `max_vms` on an artifact-sized topology spills to sparse
//! scoring (counted in [`DeltaProblem`]`::sparse_spills` — pre-PR those
//! decisions simply errored out) and returns to the dense path as soon
//! as it fits again; each transition is one O(V·N + V²) row rebuild of a
//! ≤32-row problem, i.e. negligible.  While the population fits, the
//! dense path is always taken, so pre-existing behaviour is preserved
//! bit-for-bit.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::runtime::{Meta, ScoreProblem, VmEntry, Weights};
use crate::sim::Simulator;
use crate::topology::{NodeId, Topology};
use crate::vm::{VmId, VmState};
use crate::workload::{pair_penalty, AnimalClass, AppProfile};

/// Rebuild the sparse aggregates from the per-VM caches this often
/// (bounds add/subtract float drift, same trick as `sim::incremental`).
const AGG_REBUILD_EVERY: u32 = 4096;

/// One tracked VM: the scorer-facing entry plus its cached placement row.
#[derive(Debug, Clone)]
struct TrackedVm {
    entry: VmEntry,
    /// Dense placement fractions (length = live topology nodes).
    p: Vec<f64>,
    /// Memory-distance row: `dm[k]` = Σⱼ mⱼ·d(k,j) — the locality term a
    /// vCPU on node `k` pays under this VM's memory layout.  Computed
    /// once per row update (the memory layout changes far less often than
    /// candidates are scored), turning the per-candidate locality sum
    /// from O(|p|·|m|) into O(|p|) array reads.  Summed in ascending-`j`
    /// skip-zero order, i.e. bit-identical to the inlined loop it
    /// replaced.
    dm: Vec<f64>,
}

/// Artifact-shaped dense state: the persistent padded problem and the
/// cached placement matrix, rows sorted by [`VmId`].
#[derive(Debug, Clone)]
struct DenseState {
    problem: ScoreProblem,
    order: Vec<VmId>,
    current: Vec<Vec<f64>>,
}

/// Shared aggregates for delta scoring (order-independent, so they need
/// no row bookkeeping).
#[derive(Debug, Clone)]
struct AggState {
    /// Σ cores·p per node.
    core_load: Vec<f64>,
    /// Σ bw·p per node (GB/s at full utilization).
    bw_load: Vec<f64>,
    /// Σ p per (node, animal-class index).
    class_mass: Vec<[f64; 3]>,
    /// `pen2[a][b]` = pair_penalty(a,b) + pair_penalty(b,a): both
    /// directions of a class pair, since changing one VM's row touches
    /// its victim *and* aggressor terms.
    pen2: [[f64; 3]; 3],
}

impl AggState {
    fn new(n: usize) -> Self {
        let mut pen2 = [[0.0; 3]; 3];
        for a in AnimalClass::ALL {
            for b in AnimalClass::ALL {
                pen2[a.index()][b.index()] = pair_penalty(a, b) + pair_penalty(b, a);
            }
        }
        Self {
            core_load: vec![0.0; n],
            bw_load: vec![0.0; n],
            class_mass: vec![[0.0; 3]; n],
            pen2,
        }
    }

    fn apply(&mut self, tv: &TrackedVm, sign: f64) {
        let ci = tv.entry.profile.class.index();
        let cores = tv.entry.vcpus as f64;
        let bw = tv.entry.profile.bw_gbs_per_vcpu * cores;
        for (j, &pj) in tv.p.iter().enumerate() {
            if pj != 0.0 {
                self.core_load[j] += sign * cores * pj;
                self.bw_load[j] += sign * bw * pj;
                self.class_mass[j][ci] += sign * pj;
            }
        }
    }
}

/// Effective remote-sensitivity weight, matching what
/// [`ScoreProblem::build`] writes into `s` (in f64 — the sparse path has
/// no bit-parity contract with the f32 dense matrices).
fn sens(profile: &AppProfile) -> f64 {
    let base = if profile.sensitivity.is_sensitive() { 1.0 } else { 0.3 };
    base * profile.mem_stall_frac.max(0.05)
}

/// The coordinator's persistent scoring problem.  See the module docs.
#[derive(Debug, Clone)]
pub struct DeltaProblem {
    weights: Weights,
    n_live: usize,
    /// Schedulable hw threads per node (the dense problem's `cap`).
    slots_per_node: f64,
    /// Memory-controller bandwidth per node, GB/s (the dense `bwcap`).
    node_bw: f64,
    tracked: BTreeMap<VmId, TrackedVm>,
    /// Flat row-major node-distance table (`n_live × n_live`), so the
    /// per-row `dm` precompute indexes arrays instead of calling back
    /// into the topology per (k, j) pair.  Shared (`Arc`) because the
    /// table is immutable and O(N²): the sharded coordinator builds it
    /// once and hands every zone's problem the same allocation.
    dist: std::sync::Arc<Vec<f64>>,
    servers: usize,
    /// Node -> server lookup (congestion-penalty routing).
    server_of: Vec<u32>,
    /// Route-congestion snapshot (row-major `servers × servers` mean
    /// per-hop φ from [`Simulator::route_congestion`]); empty while
    /// congestion-aware scoring is off.
    cong: Vec<f64>,
    dense: Option<DenseState>,
    /// Pristine empty dense problem (static d/cap/bwcap/w only), kept
    /// whenever the *topology* fits the artifacts so the dense path can
    /// be re-entered after a transient VM-count overgrowth.
    template: Option<ScoreProblem>,
    agg: AggState,
    ops_since_rebuild: u32,
    /// Rows patched in place (telemetry).
    pub patches: u64,
    /// Full dense-row rewrites after membership changes (telemetry).
    pub row_rebuilds: u64,
    /// Times the population outgrew the artifact row count and decisions
    /// spilled to the sparse scorer (dense resumes once it fits again).
    pub sparse_spills: u64,
}

impl DeltaProblem {
    /// Empty problem for `topo`, building the node-distance table.
    pub fn new(topo: &Topology, weights: Weights) -> Result<Self> {
        Self::with_dist(topo, weights, std::sync::Arc::new(Self::build_dist(topo)))
    }

    /// The flat row-major node-distance table `new` builds.  Exposed so
    /// the sharded coordinator can build it once and share it across Z
    /// per-zone problems via [`Self::with_dist`] (the table is O(N²) —
    /// the dominant allocation at cluster scale).
    pub(crate) fn build_dist(topo: &Topology) -> Vec<f64> {
        let n_live = topo.num_nodes();
        let mut d = vec![0.0; n_live * n_live];
        for k in 0..n_live {
            for j in 0..n_live {
                d[k * n_live + j] = topo.distance(NodeId(k), NodeId(j));
            }
        }
        d
    }

    /// [`Self::new`] with a caller-provided (shared) distance table.
    /// `dist` must be `build_dist(topo)` for the same topology.
    pub(crate) fn with_dist(
        topo: &Topology,
        weights: Weights,
        dist: std::sync::Arc<Vec<f64>>,
    ) -> Result<Self> {
        let meta = Meta::expected();
        let n_live = topo.num_nodes();
        let template = if n_live <= meta.num_nodes {
            Some(ScoreProblem::build(topo, &[], weights, meta)?)
        } else {
            None
        };
        let dense = template.as_ref().map(|t| DenseState {
            problem: t.clone(),
            order: Vec::new(),
            current: Vec::new(),
        });
        Ok(Self {
            weights,
            n_live,
            slots_per_node: (topo.spec.cores_per_node * topo.spec.threads_per_core) as f64,
            node_bw: topo.spec.mem_bw_per_node_gbs,
            tracked: BTreeMap::new(),
            dist,
            servers: topo.spec.servers,
            server_of: (0..n_live)
                .map(|i| topo.server_of_node(NodeId(i)).0 as u32)
                .collect(),
            cong: Vec::new(),
            dense,
            template,
            agg: AggState::new(n_live),
            ops_since_rebuild: 0,
            patches: 0,
            row_rebuilds: 0,
            sparse_spills: 0,
        })
    }

    /// Number of VMs with a live row.
    pub fn len(&self) -> usize {
        self.tracked.len()
    }

    /// `true` when no VM has a live row.
    pub fn is_empty(&self) -> bool {
        self.tracked.is_empty()
    }

    /// Does `id` have a live row?
    pub fn contains(&self, id: VmId) -> bool {
        self.tracked.contains_key(&id)
    }

    /// Tracked VMs in row order (sorted by id).
    pub fn ids(&self) -> impl Iterator<Item = VmId> + '_ {
        self.tracked.keys().copied()
    }

    /// `true` once the system outgrew the compiled artifact shapes and
    /// scoring runs through the sparse delta path.
    pub fn is_sparse(&self) -> bool {
        self.dense.is_none()
    }

    /// Dense artifact-shaped problem + cached placement matrix, when the
    /// system still fits the compiled shapes.
    pub fn dense(&self) -> Option<(&ScoreProblem, &[Vec<f64>])> {
        self.dense.as_ref().map(|d| (&d.problem, d.current.as_slice()))
    }

    /// Dense row index of `id`.
    pub fn row_of(&self, id: VmId) -> Option<usize> {
        self.dense.as_ref().and_then(|d| d.order.binary_search(&id).ok())
    }

    /// Current cached placement row of `id`.
    pub fn current_row(&self, id: VmId) -> Option<&[f64]> {
        self.tracked.get(&id).map(|tv| tv.p.as_slice())
    }

    // ---- synchronisation -------------------------------------------------

    /// Drain the simulator's coordinator dirty set and patch only the
    /// affected rows.  Returns the number of rows touched (0 on the
    /// common clean-path decision).
    pub fn sync(&mut self, sim: &mut Simulator) -> usize {
        let dirty = sim.drain_coord_dirty();
        self.sync_from(sim, &dirty)
    }

    /// [`Self::sync`] against a caller-provided dirty set — the sharded
    /// coordinator drains the simulator once, routes each id to its
    /// owning zone's queue, and feeds every zone's problem its own slice.
    /// With the full drained set this is bit-identical to `sync` (same
    /// ids in the same ascending order).
    pub fn sync_from(&mut self, sim: &Simulator, dirty: &std::collections::BTreeSet<VmId>) -> usize {
        if dirty.is_empty() {
            return 0;
        }
        let mut membership = false;
        let mut updated: Vec<VmId> = Vec::new();
        let mut touched = 0usize;
        for &id in dirty {
            match sim.get(id) {
                Some(mvm) if mvm.vm.state == VmState::Running => {
                    let entry = VmEntry {
                        profile: mvm.profile.clone(),
                        vcpus: mvm.vm.vcpus(),
                        mem_fractions: mvm.vm.memory_fractions(self.n_live),
                    };
                    let p = mvm.placement_fractions(&sim.topo);
                    if self.set_vm(id, entry, p) {
                        membership = true;
                    } else {
                        updated.push(id);
                    }
                    touched += 1;
                }
                _ => {
                    if self.forget(id) {
                        membership = true;
                        touched += 1;
                    }
                }
            }
        }
        self.apply_dense(membership, &updated);
        touched
    }

    /// Give `id` a row even though it is not running yet — the arrival
    /// being placed scores jointly with the running population, exactly
    /// like the rebuilt path's `include` row did.  Fails when the dense
    /// problem is at artifact capacity on an artifact-sized topology
    /// *and* the tracked population already uses every row (the same
    /// "exceeds artifact capacity" error the rebuild raised).
    pub fn ensure_row(&mut self, sim: &Simulator, id: VmId) -> Result<()> {
        let mvm = sim.get(id).ok_or_else(|| anyhow!("no such vm {id}"))?;
        let entry = VmEntry {
            profile: mvm.profile.clone(),
            vcpus: mvm.vm.vcpus(),
            mem_fractions: mvm.vm.memory_fractions(self.n_live),
        };
        let p = mvm.placement_fractions(&sim.topo);
        let fresh = self.set_vm(id, entry, p);
        self.apply_dense(fresh, &[id]);
        if let Some(d) = &self.dense {
            if d.order.len() > d.problem.meta.max_vms {
                // Unreachable (apply_dense switches to sparse first) but
                // kept as a loud guard against artifact-shape corruption.
                self.forget(id);
                self.apply_dense(true, &[]);
                return Err(anyhow!("delta problem over artifact capacity"));
            }
        }
        Ok(())
    }

    /// Upsert the tracked entry + aggregates; returns true when `id` is new.
    fn set_vm(&mut self, id: VmId, entry: VmEntry, p: Vec<f64>) -> bool {
        let fresh = match self.tracked.remove(&id) {
            Some(old) => {
                self.agg.apply(&old, -1.0);
                false
            }
            None => true,
        };
        // Per-node memory-distance row, ascending-j skip-zero — the same
        // sum [`Self::contribution`] used to run per candidate.
        let n = self.n_live;
        let nz: Vec<(usize, f64)> = entry
            .mem_fractions
            .iter()
            .enumerate()
            .filter(|(_, mj)| **mj != 0.0)
            .map(|(j, mj)| (j, *mj))
            .collect();
        let dm: Vec<f64> = (0..n)
            .map(|k| {
                let row = &self.dist[k * n..(k + 1) * n];
                nz.iter().map(|&(j, mj)| mj * row[j]).sum()
            })
            .collect();
        let tv = TrackedVm { entry, p, dm };
        self.agg.apply(&tv, 1.0);
        self.tracked.insert(id, tv);
        self.bump_agg_ops();
        fresh
    }

    /// [`Self::forget`] plus the dense-state repair `sync` would have
    /// done — for ownership transfers, where a zone must drop a row for a
    /// VM that is still running (it now belongs to another zone's
    /// problem) and no dirty event will ever arrive here to trigger it.
    /// No-op for untracked ids.
    pub(crate) fn forget_external(&mut self, id: VmId) {
        if self.forget(id) {
            self.apply_dense(true, &[]);
        }
    }

    /// Drop a VM's row + aggregate contributions; true if it was tracked.
    fn forget(&mut self, id: VmId) -> bool {
        match self.tracked.remove(&id) {
            Some(old) => {
                self.agg.apply(&old, -1.0);
                self.bump_agg_ops();
                true
            }
            None => false,
        }
    }

    fn bump_agg_ops(&mut self) {
        self.ops_since_rebuild += 1;
        if self.ops_since_rebuild >= AGG_REBUILD_EVERY {
            self.ops_since_rebuild = 0;
            let mut agg = AggState::new(self.n_live);
            for tv in self.tracked.values() {
                agg.apply(tv, 1.0);
            }
            self.agg = agg;
        }
    }

    /// Propagate tracked-state changes into the dense matrices: patch the
    /// named rows in place, or rewrite the row block after a membership
    /// change.  A population larger than the compiled row count spills to
    /// sparse-only scoring; dense resumes from the pristine template as
    /// soon as the population fits again.
    fn apply_dense(&mut self, membership: bool, updated: &[VmId]) {
        if self.dense.is_none() && membership {
            if let Some(t) = &self.template {
                if self.tracked.len() <= t.meta.max_vms {
                    // Fits again: re-enter the dense path; the membership
                    // rebuild below fills every row from the caches.
                    self.dense = Some(DenseState {
                        problem: t.clone(),
                        order: Vec::new(),
                        current: Vec::new(),
                    });
                }
            }
        }
        let Some(d) = self.dense.as_mut() else { return };
        if membership {
            if self.tracked.len() > d.problem.meta.max_vms {
                // Outgrew the artifact rows: sparse-only until it fits.
                self.dense = None;
                self.sparse_spills += 1;
                return;
            }
            let old_len = d.order.len();
            d.order.clear();
            d.order.extend(self.tracked.keys().copied());
            let classes: Vec<AnimalClass> =
                self.tracked.values().map(|tv| tv.entry.profile.class).collect();
            d.current.resize(d.order.len(), Vec::new());
            for (i, tv) in self.tracked.values().enumerate() {
                d.problem.set_entry(i, &tv.entry, &classes);
                d.current[i].clear();
                d.current[i].extend_from_slice(&tv.p);
            }
            for i in d.order.len()..old_len {
                d.problem.clear_entry(i);
            }
            d.problem.set_vm_count(d.order.len());
            self.row_rebuilds += 1;
        } else if !updated.is_empty() {
            let classes: Vec<AnimalClass> =
                self.tracked.values().map(|tv| tv.entry.profile.class).collect();
            for id in updated {
                let Ok(i) = d.order.binary_search(id) else { continue };
                let tv = &self.tracked[id];
                d.problem.set_entry(i, &tv.entry, &classes);
                d.current[i].clear();
                d.current[i].extend_from_slice(&tv.p);
                self.patches += 1;
            }
        }
    }

    // ---- delta scoring ---------------------------------------------------

    /// Contribution of VM `id` to the global score if its placement row
    /// were `p`, with every other VM fixed at its current placement and
    /// `id`'s own current contribution excluded from the aggregates.
    /// Differences between two candidates' contributions equal the
    /// differences of the full scorer's totals for the corresponding
    /// whole-system placements (the rest of the system is a constant), so
    /// the argmin over candidates is the same — at O(|p|) per candidate
    /// (the memory-distance row `dm` is precomputed per row update)
    /// instead of O(V²·N).
    pub fn contribution(&self, _topo: &Topology, id: VmId, p: &[f64]) -> f64 {
        // `_topo` kept for signature stability: distances now come from
        // the cached per-VM `dm` rows.
        self.contribution_of(&self.tracked[&id], p)
    }

    /// [`Self::contribution`] over a batch of candidate rows: the per-VM
    /// state (row lookup, entry constants, `dm` row) is resolved once and
    /// streamed against every candidate — the shape the mapper's sparse
    /// candidate loop scores decisions in.
    pub fn contribution_batch(&self, id: VmId, cands: &[&[f64]]) -> Vec<f64> {
        let tv = &self.tracked[&id];
        cands.iter().map(|p| self.contribution_of(tv, p)).collect()
    }

    /// The per-candidate scoring kernel over one VM's cached arrays.
    fn contribution_of(&self, tv: &TrackedVm, p: &[f64]) -> f64 {
        let e = &tv.entry;
        let ci = e.profile.class.index();
        let cores = e.vcpus as f64;
        let bw = e.profile.bw_gbs_per_vcpu * cores;
        let s = sens(&e.profile);

        let mut loc = 0.0;
        let mut cont = 0.0;
        let mut over = 0.0;
        let mut bwo = 0.0;
        for (k, &pk) in p.iter().enumerate() {
            if pk == 0.0 {
                continue;
            }
            // Locality: cached distance from node k to this VM's memory.
            loc += pk * tv.dm[k];

            // Contention against the *other* VMs' class mass on node k.
            let own = tv.p[k];
            let counts = &self.agg.class_mass[k];
            let mut c_k = 0.0;
            for (cj, &mass) in counts.iter().enumerate() {
                let others = mass - if cj == ci { own } else { 0.0 };
                c_k += self.agg.pen2[ci][cj] * others;
            }
            cont += pk * c_k;

            // Overload / bandwidth overload deltas vs the row-empty state.
            let lw = self.agg.core_load[k] - cores * own;
            let o_new = (lw + cores * pk - self.slots_per_node).max(0.0);
            let o_old = (lw - self.slots_per_node).max(0.0);
            over += o_new * o_new - o_old * o_old;
            let bl = self.agg.bw_load[k] - bw * own;
            let b_new = (bl + bw * pk - self.node_bw).max(0.0);
            let b_old = (bl - self.node_bw).max(0.0);
            bwo += b_new * b_new - b_old * b_old;
        }
        self.weights.locality as f64 * s * loc
            + self.weights.contention as f64 * cont
            + self.weights.overload as f64 * over
            + self.weights.bandwidth as f64 * bwo
    }

    /// Adopt a route-congestion snapshot (from
    /// [`crate::sim::Simulator::route_congestion`]) for congestion-aware
    /// candidate scoring; an empty vector turns the penalty off.
    pub fn set_congestion(&mut self, cong: Vec<f64>) {
        debug_assert!(cong.is_empty() || cong.len() == self.servers * self.servers);
        self.cong = cong;
    }

    /// Congestion penalty of placing `id`'s row at `p`: the VM's memory
    /// bandwidth demand weighted by how congested the (vCPU-server,
    /// memory-server) routes are — `Σₖⱼ pₖ·mⱼ·(φ̄(route) − 1)` scaled by
    /// demand, zero on an idle fabric or when no snapshot is loaded.
    /// Depends only on the candidate row (the snapshot is fixed across a
    /// decision), so adding it to [`Self::contribution`] preserves the
    /// exactness of delta scoring: candidate-to-candidate differences
    /// still equal full-system score differences plus the identical
    /// penalty differences.
    pub fn congestion_penalty(&self, id: VmId, p: &[f64]) -> f64 {
        if self.cong.is_empty() {
            return 0.0;
        }
        let tv = &self.tracked[&id];
        let e = &tv.entry;
        let demand = e.profile.bw_gbs_per_vcpu * e.vcpus as f64;
        let mut pen = 0.0;
        for (k, &pk) in p.iter().enumerate() {
            if pk == 0.0 {
                continue;
            }
            let sk = self.server_of[k] as usize;
            for (j, &mj) in e.mem_fractions.iter().enumerate() {
                if mj == 0.0 {
                    continue;
                }
                let sj = self.server_of[j] as usize;
                if sk != sj {
                    pen += pk * mj * (self.cong[sk * self.servers + sj] - 1.0);
                }
            }
        }
        demand * pen
    }

    /// How much worse than an ideal isolated all-local placement this
    /// VM's *current* row scores — the worst-first reshuffle priority
    /// (0 = nothing to gain).
    pub fn misplacement(&self, topo: &Topology, id: VmId) -> f64 {
        let tv = &self.tracked[&id];
        let s = sens(&tv.entry.profile);
        let m_total: f64 = tv.entry.mem_fractions.iter().sum();
        let p_total: f64 = tv.p.iter().sum();
        // Best possible locality: every access at local distance (10).
        let floor = self.weights.locality as f64 * s * 10.0 * m_total * p_total;
        (self.contribution(topo, id, &tv.p) - floor).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native;
    use crate::sim::SimConfig;
    use crate::topology::CpuId;
    use crate::util::rng::Rng;
    use crate::vm::VmType;
    use crate::workload::App;

    /// The pre-PR rebuild path, reproduced for the parity checks.
    fn rebuild(sim: &Simulator, weights: Weights) -> (ScoreProblem, Vec<VmId>, Vec<Vec<f64>>) {
        let mut order: Vec<VmId> = sim
            .vms()
            .filter(|(_, m)| m.vm.state == VmState::Running)
            .map(|(id, _)| *id)
            .collect();
        order.sort();
        let n = sim.topo.num_nodes();
        let entries: Vec<VmEntry> = order
            .iter()
            .map(|id| {
                let mvm = sim.get(*id).unwrap();
                VmEntry {
                    profile: mvm.profile.clone(),
                    vcpus: mvm.vm.vcpus(),
                    mem_fractions: mvm.vm.memory_fractions(n),
                }
            })
            .collect();
        let problem =
            ScoreProblem::build(&sim.topo, &entries, weights, Meta::expected()).unwrap();
        let current: Vec<Vec<f64>> =
            order.iter().map(|id| sim.get(*id).unwrap().placement_fractions(&sim.topo)).collect();
        (problem, order, current)
    }

    fn assert_dense_matches_rebuild(dp: &DeltaProblem, sim: &Simulator) {
        let (want, order, current) = rebuild(sim, Weights::default());
        let (got, got_current) = dp.dense().expect("paper topology stays dense");
        assert_eq!(dp.ids().collect::<Vec<_>>(), order, "row order diverged");
        assert_eq!(got.vms, want.vms);
        assert_eq!(got.m, want.m, "memory matrix diverged");
        assert_eq!(got.c, want.c, "class matrix diverged");
        assert_eq!(got.s, want.s, "sensitivity diverged");
        assert_eq!(got.cores, want.cores);
        assert_eq!(got.bw, want.bw);
        assert_eq!(got_current, current.as_slice(), "placement cache diverged");
    }

    #[test]
    fn dense_stays_bit_identical_to_rebuild_under_churn() {
        let mut rng = Rng::new(11);
        let mut sim = Simulator::new(Topology::paper(), SimConfig::pinned(11));
        let mut dp = DeltaProblem::new(&sim.topo, Weights::default()).unwrap();
        let mut ids: Vec<VmId> = Vec::new();
        for step in 0..40 {
            match rng.below(4) {
                0 => {
                    let id = sim.create(VmType::Small, *rng.choose(&App::ALL));
                    let base = rng.below(280);
                    let cpus: Vec<CpuId> = (base..base + 4).map(CpuId).collect();
                    sim.pin_all(id, &cpus).unwrap();
                    sim.place_memory(id, &[(NodeId(rng.below(36)), 1.0)]).unwrap();
                    sim.start(id).unwrap();
                    ids.push(id);
                }
                1 if !ids.is_empty() => {
                    let id = ids.remove(rng.below(ids.len()));
                    sim.destroy(id).unwrap();
                }
                2 if !ids.is_empty() => {
                    let id = ids[rng.below(ids.len())];
                    sim.place_memory(id, &[(NodeId(rng.below(36)), 1.0)]).unwrap();
                }
                _ => {
                    sim.step();
                }
            }
            dp.sync(&mut sim);
            assert_dense_matches_rebuild(&dp, &sim);
            let _ = step;
        }
    }

    #[test]
    fn outgrowing_artifact_capacity_switches_to_sparse() {
        let mut sim = Simulator::new(Topology::paper(), SimConfig::pinned(3));
        let mut dp = DeltaProblem::new(&sim.topo, Weights::default()).unwrap();
        for k in 0..40 {
            let id = sim.create(VmType::Small, App::Sockshop);
            let cpus: Vec<CpuId> = (k * 4..k * 4 + 4).map(CpuId).collect();
            sim.pin_all(id, &cpus).unwrap();
            sim.start(id).unwrap();
        }
        dp.sync(&mut sim);
        assert_eq!(dp.len(), 40);
        assert!(dp.is_sparse(), "33+ VMs must leave the dense artifacts behind");
        assert!(dp.dense().is_none());
        assert_eq!(dp.sparse_spills, 1);
        // Delta scoring still ranks candidates sanely: of two *empty*
        // (contention- and overload-free) nodes, the one closer to the
        // victim's memory (first-touch on node 0) must score lower.
        // 160 vcpus fill nodes 0..19; nodes 20..35 are empty.
        let victim = dp.ids().next().unwrap();
        let d0 = |n: usize| sim.topo.distance(NodeId(0), NodeId(n));
        let near = (20..36).min_by(|a, b| d0(*a).partial_cmp(&d0(*b)).unwrap()).unwrap();
        let far = (20..36).max_by(|a, b| d0(*a).partial_cmp(&d0(*b)).unwrap()).unwrap();
        assert!(d0(near) < d0(far), "torus must expose distinct hop counts");
        let cand = |n: usize| {
            let mut p = vec![0.0; 36];
            p[n] = 1.0;
            p
        };
        let c_near = dp.contribution(&sim.topo, victim, &cand(near));
        let c_far = dp.contribution(&sim.topo, victim, &cand(far));
        assert!(c_near >= 0.0 && c_far >= 0.0, "contributions are sums of penalties");
        assert!(c_near < c_far, "closer empty node must score better: {c_near} vs {c_far}");

        // Destroys shrink the population back under the artifact row
        // count: the dense path resumes from the template and is again
        // bit-identical to a fresh rebuild.
        let ids: Vec<VmId> = dp.ids().collect();
        for id in ids.iter().take(20) {
            sim.destroy(*id).unwrap();
        }
        dp.sync(&mut sim);
        assert!(!dp.is_sparse(), "population fits again -> dense resumes");
        assert_dense_matches_rebuild(&dp, &sim);
    }

    #[test]
    fn contribution_deltas_match_full_scorer() {
        // The delta-vs-full oracle at module level: for random candidate
        // rows, contribution differences must match the full native
        // scorer's total differences (f32 tolerance).
        let mut rng = Rng::new(7);
        let mut sim = Simulator::new(Topology::paper(), SimConfig::pinned(7));
        let mut ids = Vec::new();
        for k in 0..6 {
            let id = sim.create(VmType::Small, *rng.choose(&App::ALL));
            let cpus: Vec<CpuId> = (k * 8..k * 8 + 4).map(CpuId).collect();
            sim.pin_all(id, &cpus).unwrap();
            sim.place_memory(id, &[(NodeId(rng.below(36)), 1.0)]).unwrap();
            sim.start(id).unwrap();
            ids.push(id);
        }
        let mut dp = DeltaProblem::new(&sim.topo, Weights::default()).unwrap();
        dp.sync(&mut sim);
        let (problem, current) = dp.dense().unwrap();
        let victim = ids[2];
        let row = dp.row_of(victim).unwrap();

        let mut cands: Vec<Vec<f64>> = Vec::new();
        for _ in 0..6 {
            let mut p = vec![0.0; 36];
            for f in rng.simplex(3) {
                p[rng.below(36)] += f;
            }
            let sum: f64 = p.iter().sum();
            p.iter_mut().for_each(|x| *x /= sum);
            cands.push(p);
        }
        let full: Vec<f64> = cands
            .iter()
            .map(|cand| {
                let mut rows = current.to_vec();
                rows[row] = cand.clone();
                native::score_one(problem, &rows).total as f64
            })
            .collect();
        let delta: Vec<f64> =
            cands.iter().map(|cand| dp.contribution(&sim.topo, victim, cand)).collect();
        for i in 0..cands.len() {
            for j in 0..cands.len() {
                let want = full[i] - full[j];
                let got = delta[i] - delta[j];
                assert!(
                    (want - got).abs() <= 1e-3 * (1.0 + want.abs()),
                    "delta mismatch ({i},{j}): full {want} vs delta {got}"
                );
            }
        }
    }

    #[test]
    fn cached_dm_rows_and_batch_match_the_inlined_kernel() {
        // The precomputed memory-distance rows (and the batch entry
        // point) must reproduce the old per-candidate inlined sum
        // bit-for-bit: same ascending-j skip-zero order, same values.
        let mut rng = Rng::new(13);
        let mut sim = Simulator::new(Topology::paper(), SimConfig::pinned(13));
        let mut ids = Vec::new();
        for k in 0..5 {
            let id = sim.create(VmType::Small, *rng.choose(&App::ALL));
            let cpus: Vec<CpuId> = (k * 8..k * 8 + 4).map(CpuId).collect();
            sim.pin_all(id, &cpus).unwrap();
            sim.place_memory(id, &[(NodeId(rng.below(36)), 1.0)]).unwrap();
            sim.start(id).unwrap();
            ids.push(id);
        }
        let mut dp = DeltaProblem::new(&sim.topo, Weights::default()).unwrap();
        dp.sync(&mut sim);
        let victim = ids[1];
        let e = &dp.tracked[&victim].entry;
        let mem = e.mem_fractions.clone();
        let mut cands: Vec<Vec<f64>> = Vec::new();
        for _ in 0..8 {
            let mut p = vec![0.0; 36];
            for f in rng.simplex(2) {
                p[rng.below(36)] += f;
            }
            cands.push(p);
        }
        // Reference: the pre-cache kernel shape for the locality term.
        let loc_ref = |p: &[f64]| -> f64 {
            let mut loc = 0.0;
            for (k, &pk) in p.iter().enumerate() {
                if pk == 0.0 {
                    continue;
                }
                let mut dm = 0.0;
                for (j, &mj) in mem.iter().enumerate() {
                    if mj != 0.0 {
                        dm += mj * sim.topo.distance(NodeId(k), NodeId(j));
                    }
                }
                loc += pk * dm;
            }
            loc
        };
        for (k, &d) in dp.tracked[&victim].dm.iter().enumerate() {
            let mut want = 0.0;
            for (j, &mj) in mem.iter().enumerate() {
                if mj != 0.0 {
                    want += mj * sim.topo.distance(NodeId(k), NodeId(j));
                }
            }
            assert_eq!(d, want, "dm[{k}] diverged from the inlined sum");
        }
        let w_loc = Weights::default().locality as f64 * super::sens(&e.profile);
        let single: Vec<f64> =
            cands.iter().map(|p| dp.contribution(&sim.topo, victim, p)).collect();
        let rows: Vec<&[f64]> = cands.iter().map(|p| p.as_slice()).collect();
        let batch = dp.contribution_batch(victim, &rows);
        assert_eq!(batch, single, "batch must equal per-candidate calls bitwise");
        let d_loc_ref = w_loc * (loc_ref(&cands[0]) - loc_ref(&cands[1]));
        assert!(
            d_loc_ref.is_finite() && single.iter().all(|s| s.is_finite()),
            "kernel produces finite scores"
        );
    }

    #[test]
    fn congestion_penalty_prefers_uncongested_routes() {
        let mut sim = Simulator::new(Topology::paper(), SimConfig::pinned(6));
        // Victim with memory on server 1 (nodes 6..12).
        let id = sim.create(VmType::Small, App::Stream);
        sim.pin_all(id, &(48..52).map(CpuId).collect::<Vec<_>>()).unwrap();
        sim.place_memory(id, &[(NodeId(6), 1.0)]).unwrap();
        sim.start(id).unwrap();
        let mut dp = DeltaProblem::new(&sim.topo, Weights::default()).unwrap();
        dp.sync(&mut sim);
        // No snapshot: penalty off.
        let local = {
            let mut p = vec![0.0; 36];
            p[6] = 1.0;
            p
        };
        let remote = {
            let mut p = vec![0.0; 36];
            p[0] = 1.0; // server 0: vCPUs would pull memory over s0<->s1
            p
        };
        assert_eq!(dp.congestion_penalty(id, &remote), 0.0);
        // Synthetic snapshot: route s0 -> s1 congested 5x, rest idle.
        let servers = sim.topo.spec.servers;
        let mut cong = vec![1.0; servers * servers];
        cong[servers] = 5.0; // (1, 0)
        cong[1] = 5.0; // (0, 1)
        dp.set_congestion(cong);
        let pen_remote = dp.congestion_penalty(id, &remote);
        let pen_local = dp.congestion_penalty(id, &local);
        assert_eq!(pen_local, 0.0, "same-server flows pay nothing");
        assert!(pen_remote > 0.0, "cross-server flow over hot route must pay");
        dp.set_congestion(Vec::new());
        assert_eq!(dp.congestion_penalty(id, &remote), 0.0);
    }

    #[test]
    fn misplacement_is_zero_for_ideal_and_positive_for_remote() {
        let mut sim = Simulator::new(Topology::paper(), SimConfig::pinned(5));
        let good = sim.create(VmType::Small, App::Stream);
        sim.pin_all(good, &(0..4).map(CpuId).collect::<Vec<_>>()).unwrap();
        sim.place_memory(good, &[(NodeId(0), 1.0)]).unwrap();
        sim.start(good).unwrap();
        let bad = sim.create(VmType::Small, App::Stream);
        sim.pin_all(bad, &(8..12).map(CpuId).collect::<Vec<_>>()).unwrap();
        sim.place_memory(bad, &[(NodeId(24), 1.0)]).unwrap();
        sim.start(bad).unwrap();
        let mut dp = DeltaProblem::new(&sim.topo, Weights::default()).unwrap();
        dp.sync(&mut sim);
        let m_good = dp.misplacement(&sim.topo, good);
        let m_bad = dp.misplacement(&sim.topo, bad);
        assert!(m_good < 1e-9, "local isolated VM should have ~0 misplacement: {m_good}");
        assert!(m_bad > 1.0, "2-hop remote VM must rank high: {m_bad}");
        assert!(m_bad > m_good);
    }
}
