//! The benefit matrix (paper Table 4): per (isolation level × animal
//! class) estimates, on a 1–10 scale, of how much a class gains from being
//! moved to its own socket / NUMA node / server.
//!
//! "This table is dynamically updated during runtime and, hence, the
//! algorithm can make better mapping decisions over time" (§4.1): after a
//! remap the coordinator measures the realized relative-performance gain
//! and folds it into the matrix by EMA — see [`BenefitMatrix::observe`].

use crate::workload::classes::{initial_benefit, AnimalClass, IsolationLevel};

/// Learned copy of Table 4.
#[derive(Debug, Clone)]
pub struct BenefitMatrix {
    /// `[level][class]`, 1–10.
    values: [[f64; 3]; 3],
    /// EMA smoothing for observations.
    alpha: f64,
    /// Number of observations folded in (telemetry / tests).
    observations: u64,
}

impl Default for BenefitMatrix {
    fn default() -> Self {
        Self::new(0.3)
    }
}

impl BenefitMatrix {
    /// Table 4's initial values with EMA smoothing factor `alpha`.
    pub fn new(alpha: f64) -> Self {
        let mut values = [[0.0; 3]; 3];
        for (li, level) in IsolationLevel::ALL.iter().enumerate() {
            for (ci, class) in AnimalClass::ALL.iter().enumerate() {
                values[li][ci] = initial_benefit(*level, *class);
            }
        }
        Self { values, alpha, observations: 0 }
    }

    /// Current 1–10 benefit estimate of giving `class` its own `level`.
    pub fn get(&self, level: IsolationLevel, class: AnimalClass) -> f64 {
        self.values[level_index(level)][class.index()]
    }

    /// Isolation levels for `class`, best benefit first — the order in
    /// which the remap search tries candidate moves.  Returns a fixed
    /// array: this sits in the remap hot loop and must not allocate.
    pub fn ranked_levels(&self, class: AnimalClass) -> [IsolationLevel; 3] {
        let mut levels = IsolationLevel::ALL;
        levels.sort_by(|a, b| {
            self.get(*b, class).partial_cmp(&self.get(*a, class)).unwrap()
        });
        levels
    }

    /// Fold in an observed relative gain from a move of `class` to its own
    /// `level` domain.  `gain` is fractional (0.5 = +50% throughput); it is
    /// mapped onto the 1–10 scale (1 + 9·clamp(gain, 0, 1)) and EMA'd.
    pub fn observe(&mut self, level: IsolationLevel, class: AnimalClass, gain: f64) {
        let target = 1.0 + 9.0 * gain.clamp(0.0, 1.0);
        let v = &mut self.values[level_index(level)][class.index()];
        *v = (1.0 - self.alpha) * *v + self.alpha * target;
        *v = v.clamp(1.0, 10.0);
        self.observations += 1;
    }

    /// Observations folded in so far (telemetry / tests).
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Expected fractional gain (0..1) of giving `class` its best
    /// isolation level — the inverse of the 1–10 mapping `observe` applies.
    /// The worst-first reshuffle uses this learned prior to scale per-VM
    /// priorities: classes that historically gained more from isolation
    /// are revisited first.
    pub fn expected_gain(&self, class: AnimalClass) -> f64 {
        let best = IsolationLevel::ALL
            .iter()
            .map(|l| self.get(*l, class))
            .fold(f64::MIN, f64::max);
        ((best - 1.0) / 9.0).clamp(0.0, 1.0)
    }

    /// Render as the paper's Table 4 layout.
    pub fn to_table(&self) -> crate::util::table::Table {
        let mut t = crate::util::table::Table::new("Benefit Matrix (Table 4)")
            .header(&["", "Sheep", "Rabbit", "Devil"]);
        for level in IsolationLevel::ALL {
            t.row_f(
                level.name(),
                &AnimalClass::ALL.map(|c| self.get(level, c)),
                1,
            );
        }
        t
    }
}

fn level_index(level: IsolationLevel) -> usize {
    match level {
        IsolationLevel::Socket => 0,
        IsolationLevel::NumaNode => 1,
        IsolationLevel::ServerNode => 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use AnimalClass::*;
    use IsolationLevel::*;

    #[test]
    fn starts_at_table4() {
        let b = BenefitMatrix::default();
        assert_eq!(b.get(Socket, Sheep), 1.0);
        assert_eq!(b.get(NumaNode, Rabbit), 5.0);
        assert_eq!(b.get(ServerNode, Devil), 9.0);
        assert_eq!(b.observations(), 0);
    }

    #[test]
    fn ranked_levels_prefer_big_benefit() {
        let b = BenefitMatrix::default();
        // Devils: server (9) > numa (8) > socket (7).
        assert_eq!(b.ranked_levels(Devil), [ServerNode, NumaNode, Socket]);
    }

    #[test]
    fn observe_moves_value_toward_observation() {
        let mut b = BenefitMatrix::new(0.5);
        let before = b.get(Socket, Rabbit); // 4.0
        b.observe(Socket, Rabbit, 1.0); // target 10
        let after = b.get(Socket, Rabbit);
        assert!(after > before);
        assert!((after - 7.0).abs() < 1e-9); // 0.5*4 + 0.5*10
        assert_eq!(b.observations(), 1);
    }

    #[test]
    fn observe_no_gain_decays_value() {
        let mut b = BenefitMatrix::new(0.5);
        b.observe(ServerNode, Devil, 0.0); // target 1
        assert!((b.get(ServerNode, Devil) - 5.0).abs() < 1e-9); // 0.5*9 + 0.5*1
    }

    #[test]
    fn values_stay_in_1_to_10() {
        let mut b = BenefitMatrix::new(1.0);
        for _ in 0..20 {
            b.observe(Socket, Sheep, 100.0);
            b.observe(ServerNode, Devil, -5.0);
        }
        assert!(b.get(Socket, Sheep) <= 10.0);
        assert!(b.get(ServerNode, Devil) >= 1.0);
    }

    #[test]
    fn learning_can_reorder_levels() {
        let mut b = BenefitMatrix::new(0.8);
        // Rabbits empirically gain most from their own socket here.
        for _ in 0..5 {
            b.observe(Socket, Rabbit, 1.0);
            b.observe(ServerNode, Rabbit, 0.0);
        }
        assert_eq!(b.ranked_levels(Rabbit)[0], Socket);
    }

    #[test]
    fn expected_gain_tracks_best_level() {
        let b = BenefitMatrix::default();
        // Devils: best initial level is ServerNode at 9 -> (9-1)/9.
        assert!((b.expected_gain(Devil) - 8.0 / 9.0).abs() < 1e-9);
        let mut b = BenefitMatrix::new(1.0);
        for level in IsolationLevel::ALL {
            b.observe(level, Sheep, 0.0); // every level decays to 1
        }
        assert_eq!(b.expected_gain(Sheep), 0.0);
    }

    #[test]
    fn table_rendering_contains_levels() {
        let s = BenefitMatrix::default().to_table().render();
        assert!(s.contains("Socket"));
        assert!(s.contains("Numa Node"));
        assert!(s.contains("Server Node"));
    }
}
