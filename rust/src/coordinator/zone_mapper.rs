//! Per-zone mapper shard and the zone-partitioned dirty router.
//!
//! The sharded coordinator ([`super::sharded`]) partitions the cluster by
//! [`ZoneMap`] into contiguous server bands and gives each band its own
//! [`SmMapper`] whose candidate searches never leave the band.  Two
//! pieces of shared state make that work:
//!
//! * the [`DirtyRouter`] — drains the simulator's coordinator dirty set
//!   once per sync and splits the ids across per-zone queues by VM
//!   ownership, and
//! * a cluster-wide `Arc<Vec<f64>>` node-distance table, built once and
//!   shared by every zone's delta problem (the table is O(nodes²)).
//!
//! Both are touched once per mapper sync — never per candidate, never
//! per score — so the decision hot path stays lock-free.

use std::collections::{BTreeSet, HashMap};
use std::ops::Range;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::mapper::{MapperConfig, SmMapper};
use crate::runtime::Scorer;
use crate::sim::Simulator;
use crate::topology::ZoneMap;
use crate::vm::VmId;

/// Routes the simulator's coordinator dirty set to per-zone queues.
///
/// Ownership rule: a VM belongs to the zone that placed it (recorded at
/// arrival, updated on a cross-zone exchange).  A dirty id with no
/// ownership record falls back to the zone of its first pinned vCPU, so
/// membership changes still reach the mapper that tracks the row; ids
/// with neither (a VM destroyed before placement) drain to zone 0, where
/// forgetting an untracked row is a no-op.
pub(crate) struct DirtyRouter {
    zones: ZoneMap,
    owner: HashMap<VmId, usize>,
    queues: Vec<BTreeSet<VmId>>,
}

impl DirtyRouter {
    pub(crate) fn new(zones: ZoneMap) -> Self {
        let n = zones.zones();
        DirtyRouter { zones, owner: HashMap::new(), queues: vec![BTreeSet::new(); n] }
    }

    /// Drain the simulator once and fan the dirty ids out to the owning
    /// zones' queues.  Ownership records of departed VMs are dropped on
    /// the way through (their final dirty bit still reaches the owner so
    /// the scoring row is forgotten).
    pub(crate) fn pump(&mut self, sim: &mut Simulator) {
        let split = sim.drain_coord_dirty_zoned(&self.zones, |id| self.owner.get(&id).copied());
        for (zone, ids) in split.into_iter().enumerate() {
            for id in ids {
                if sim.get(id).is_none() {
                    self.owner.remove(&id);
                }
                self.queues[zone].insert(id);
            }
        }
    }

    /// Take zone `zone`'s pending dirty ids, leaving an empty queue.
    pub(crate) fn take(&mut self, zone: usize) -> BTreeSet<VmId> {
        std::mem::take(&mut self.queues[zone])
    }

    /// Record `id` as owned by `zone` (called at arrival and on every
    /// cross-zone exchange).  Any queue entry from before the ownership
    /// record existed (the create-time dirty bit routes to the fallback
    /// queue) is dropped, so no other zone can adopt the row at its next
    /// sync — the owner's own pending bit is re-established by the
    /// caller where one is needed ([`Self::reroute`] on an exchange; the
    /// post-pin dirty bit on an arrival).
    pub(crate) fn set_owner(&mut self, id: VmId, zone: usize) {
        for q in &mut self.queues {
            q.remove(&id);
        }
        self.owner.insert(id, zone);
    }

    /// Current owner zone of a VM, if it was placed by a zone mapper.
    pub(crate) fn owner_of(&self, id: VmId) -> Option<usize> {
        self.owner.get(&id).copied()
    }

    /// Re-route an already-queued id after an ownership transfer: drop
    /// it from `from`'s queue and mark it pending for `to`, so the donor
    /// can never re-adopt a row it just forgot and the receiver re-syncs
    /// the row it just pinned.
    pub(crate) fn reroute(&mut self, id: VmId, from: usize, to: usize) {
        self.queues[from].remove(&id);
        self.queues[to].insert(id);
    }
}

/// One zone's mapper plus its static server band.
pub(crate) struct ZoneShard {
    pub(crate) mapper: SmMapper,
    pub(crate) zone: usize,
    /// Half-open server-id band this shard owns (from [`ZoneMap`]).
    pub(crate) servers: Range<usize>,
}

impl ZoneShard {
    /// Build one shard: a fresh [`SmMapper`] put into sharded mode over
    /// this zone's server band, wired to the shared router and distance
    /// table.
    pub(crate) fn new(
        cfg: MapperConfig,
        scorer: Scorer,
        zone: usize,
        zones: &ZoneMap,
        router: Arc<Mutex<DirtyRouter>>,
        dist: Arc<Vec<f64>>,
    ) -> ZoneShard {
        let servers = zones.servers_of(zone);
        let mut mapper = SmMapper::new(cfg, scorer);
        mapper.set_shard(zone, servers.clone(), router, dist);
        ZoneShard { mapper, zone, servers }
    }

    /// Schedulable free CPUs in this zone's band (available nodes only).
    /// Drives the deterministic arrival routing: most-free zone first.
    pub(crate) fn free_cpus(&self, sim: &Simulator) -> usize {
        zone_free_cpus(sim, &self.servers)
    }

    /// Aggregate pressure summary for the rebalancer: `(slot
    /// utilization, mean windowed rel-perf of tracked VMs)`.  Utilization
    /// counts only available (non-drained) nodes; a fully drained band
    /// reports utilization 1.0 so it can never be picked as a receiver.
    pub(crate) fn pressure(&self, sim: &Simulator) -> (f64, f64) {
        let topo = &sim.topo;
        let per_node = topo.spec.cores_per_node * topo.spec.threads_per_core;
        let slots = sim.slots();
        let mut cap = 0usize;
        let mut free = 0usize;
        for server in self.servers.clone() {
            for node in topo.nodes_of_server(crate::topology::ServerId(server)) {
                if slots.node_available(node) {
                    cap += per_node;
                    free += slots.free_count(node);
                }
            }
        }
        let util = if cap == 0 { 1.0 } else { 1.0 - free as f64 / cap as f64 };
        let mut rel_sum = 0.0;
        let mut rel_n = 0usize;
        for id in self.mapper.tracked_ids() {
            if let Some((_, _, rel)) = self.mapper.window_counters(sim, id) {
                rel_sum += rel;
                rel_n += 1;
            }
        }
        let rel = if rel_n == 0 { 1.0 } else { rel_sum / rel_n as f64 };
        (util, rel)
    }
}

/// Schedulable free CPUs over a server band (available nodes only).
pub(crate) fn zone_free_cpus(sim: &Simulator, servers: &Range<usize>) -> usize {
    let slots = sim.slots();
    servers
        .clone()
        .flat_map(|s| sim.topo.nodes_of_server(crate::topology::ServerId(s)))
        .filter(|n| slots.node_available(*n))
        .map(|n| slots.free_count(n))
        .sum()
}

/// Result of one exchange attempt, for [`super::sharded::ShardStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum ExchangeOutcome {
    /// The VM was re-pinned into the receiving zone.
    Moved,
    /// The receiving zone had no candidate slot; ownership unchanged.
    NoCapacity,
}

/// Move one VM from `donor` to `receiver`: the receiving shard scores
/// and pins a candidate inside its own band (bounded migration budget),
/// then ownership transfers and the donor forgets every trace of the
/// row.  On failure the receiver's trial row is scrubbed and the donor
/// keeps the VM — the exchange either fully happens or leaves no trace.
pub(crate) fn exchange_vm(
    sim: &mut Simulator,
    donor: &mut ZoneShard,
    receiver: &mut ZoneShard,
    router: &Mutex<DirtyRouter>,
    id: VmId,
    budget_gb: f64,
) -> Result<ExchangeOutcome> {
    if receiver.mapper.evacuate_vm(sim, id, budget_gb, "exchange")? {
        donor.mapper.forget_vm(id);
        let mut r = router.lock().expect("dirty router poisoned");
        r.set_owner(id, receiver.zone);
        r.reroute(id, donor.zone, receiver.zone);
        Ok(ExchangeOutcome::Moved)
    } else {
        // evacuate_vm may have ensured a trial row before discovering
        // there was no in-band candidate; drop it so the receiver's
        // problem only ever tracks VMs it owns.
        receiver.mapper.forget_vm(id);
        Ok(ExchangeOutcome::NoCapacity)
    }
}
