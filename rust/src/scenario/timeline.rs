//! Scenario event model + seed-deterministic timeline generation.
//!
//! A [`ScenarioSpec`] is declarative: rates, windows and schedules.
//! [`ScenarioSpec::timeline`] expands it into a concrete, sorted list of
//! `(tick, event)` pairs using only the given seed (salted by the
//! scenario name, so every scenario of a suite gets an independent but
//! reproducible stream).  Expansion is pure: generating twice from the
//! same `(spec, seed)` yields identical vectors.

use crate::util::rng::Rng;
use crate::vm::VmType;
use crate::workload::trace::Arrival;
use crate::workload::{App, Phase};

/// One scheduled cluster event.  Target VMs are resolved at application
/// time by deterministic rules (oldest churn VM departs; phase shifts
/// round-robin over running VMs in id order).
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioEvent {
    /// A VM arrives (admission may queue it when capacity is short).
    Arrive { vm_type: VmType, app: App },
    /// The oldest still-running churn VM departs.
    Depart,
    /// The next running VM (round-robin) shifts execution phase.
    PhaseShift { phase: Phase },
    /// Cluster-wide load multiplier (diurnal wave sample).
    SetLoad { scale: f64 },
    /// Planned server drain (maintenance).
    Drain { server: usize },
    /// The drained server comes back.
    Recover { server: usize },
    /// Fabric-link degradation to `scale` of nominal bandwidth/capacity.
    DegradeFabric { scale: f64 },
    RestoreFabric,
    /// One fabric link pair fails (asymmetric failure; traffic re-routes).
    LinkDown { a: usize, b: usize },
    /// The failed link pair comes back.
    LinkRestore { a: usize, b: usize },
    /// Abrupt fail-stop crash (chaos): resident VMs die, links drop.
    /// With `rack`, the whole torus row of `server` crashes in the same
    /// tick (correlated failure) — membership is resolved by the runner
    /// from the live topology.
    Crash { server: usize, rack: bool },
    /// A crashed server (or rack) returns, empty.
    CrashRecover { server: usize, rack: bool },
}

/// Diurnal load wave: `scale(t) = 1 + amplitude · sin(2πt / period)`,
/// sampled every `every` ticks (floored at 0.1).
#[derive(Debug, Clone, Copy)]
pub struct DiurnalSpec {
    pub period: u64,
    pub amplitude: f64,
    pub every: u64,
}

/// A planned drain window.
#[derive(Debug, Clone, Copy)]
pub struct DrainWindow {
    pub at: u64,
    pub server: usize,
    pub recover_at: u64,
}

/// A fabric-degradation window.
#[derive(Debug, Clone, Copy)]
pub struct FabricWindow {
    pub at: u64,
    pub scale: f64,
    pub restore_at: u64,
}

/// A single-link failure window (`a <-> b` must be a torus-adjacent
/// server pair).
#[derive(Debug, Clone, Copy)]
pub struct LinkWindow {
    pub at: u64,
    pub a: usize,
    pub b: usize,
    pub restore_at: u64,
}

/// A crash window: `server` (or, with `rack`, its whole torus row) dies
/// abruptly at `at` and returns *empty* at `recover_at` (`0` or past the
/// horizon = never within the run).
#[derive(Debug, Clone, Copy)]
pub struct CrashWindow {
    pub at: u64,
    pub server: usize,
    /// Correlated failure: take down the whole torus row of `server`.
    pub rack: bool,
    pub recover_at: u64,
}

/// Seed-deterministic crash storm: `count` independent single-server
/// crashes drawn uniformly on `[from, to)` over `servers` hosts, each
/// returning empty after `outage` ticks (`0` = never).  Draws come from
/// a dedicated RNG stream forked only when a storm is present, so
/// storm-free scenarios expand bit-identically to before.
#[derive(Debug, Clone, Copy)]
pub struct CrashStormSpec {
    pub from: u64,
    pub to: u64,
    pub count: usize,
    /// Hosts to draw crash targets from (the runner's topology size).
    pub servers: usize,
    pub outage: u64,
}

/// Declarative description of one scenario.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    /// Total ticks to simulate.
    pub horizon: u64,
    /// Ticks skipped before perf samples count (placement settle time).
    pub warmup: u64,
    /// Steady background population (admitted at their `at_tick`).
    pub initial: Vec<Arrival>,
    /// Poisson arrival rate of churn VMs (events/tick; 0 = off).
    pub arrive_rate: f64,
    /// Poisson departure rate of churn VMs (events/tick; 0 = off).
    pub depart_rate: f64,
    /// First tick at which churn may fire.
    pub churn_from: u64,
    /// Phase-shift period in ticks (0 = off); phases cycle
    /// memory-heavy → compute-heavy → ws-growth → baseline.
    pub phase_every: u64,
    pub diurnal: Option<DiurnalSpec>,
    pub drains: Vec<DrainWindow>,
    pub fabric: Vec<FabricWindow>,
    /// Individual link failures (asymmetric fabric degradation).
    pub link_downs: Vec<LinkWindow>,
    /// Abrupt crash windows (chaos; empty for the legacy scenarios).
    pub crashes: Vec<CrashWindow>,
    /// Randomized crash storm (chaos; `None` for the legacy scenarios).
    pub crash_storm: Option<CrashStormSpec>,
    /// Gate arrivals (and restarts) through the
    /// [`crate::coordinator::AdmissionController`] headroom policy
    /// instead of admitting unconditionally.  Off for the legacy
    /// scenarios (bit-parity); on for the chaos suite.
    pub admission: bool,
    /// Run the simulator with link-level congestion feedback on (the
    /// fabric ledger shaping perf and migration budgets).  Off for the
    /// legacy scenarios, which stay bit-identical to their pre-fabric
    /// runs; on for `degraded-link`.
    pub fabric_feedback: bool,
}

/// FNV-1a — stable name salt so each scenario in a suite draws an
/// independent, reproducible stream from the same base seed.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Poisson event ticks on `[from, to)` via exponential inter-arrivals.
fn poisson_ticks(rng: &mut Rng, rate: f64, from: u64, to: u64) -> Vec<u64> {
    let mut out = Vec::new();
    if rate <= 0.0 {
        return out;
    }
    let mut t = from as f64;
    loop {
        t += -rng.f64().max(1e-12).ln() / rate;
        if t >= to as f64 {
            return out;
        }
        out.push(t as u64);
    }
}

/// Apps the churn generator draws from (no huge VMs: churn is the
/// small/medium tide on top of the steady background).
const CHURN_APPS: [App; 7] =
    [App::Derby, App::Fft, App::Sockshop, App::Mpegaudio, App::Stream, App::Sor, App::Sunflow];

const PHASE_CYCLE: [Phase; 4] =
    [Phase::MemoryHeavy, Phase::ComputeHeavy, Phase::WorkingSetGrowth, Phase::Baseline];

impl ScenarioSpec {
    /// The scenario's simulator/timeline seed for a given base seed.
    pub fn salted_seed(&self, seed: u64) -> u64 {
        seed ^ fnv1a(&self.name)
    }

    /// Expand into a concrete timeline, sorted by tick (stable: ties keep
    /// generation order — churn, phases, diurnal, drains, fabric).
    pub fn timeline(&self, seed: u64) -> Vec<(u64, ScenarioEvent)> {
        let mut rng = Rng::new(self.salted_seed(seed) ^ 0x5CE1_A210);
        let mut events: Vec<(u64, ScenarioEvent)> = Vec::new();

        let mut arrive_rng = rng.fork(1);
        let mut attr_rng = rng.fork(2);
        for t in poisson_ticks(&mut arrive_rng, self.arrive_rate, self.churn_from, self.horizon)
        {
            let vm_type = if attr_rng.chance(0.7) { VmType::Small } else { VmType::Medium };
            let app = *attr_rng.choose(&CHURN_APPS);
            events.push((t, ScenarioEvent::Arrive { vm_type, app }));
        }
        let mut depart_rng = rng.fork(3);
        for t in poisson_ticks(&mut depart_rng, self.depart_rate, self.churn_from, self.horizon)
        {
            events.push((t, ScenarioEvent::Depart));
        }

        if self.phase_every > 0 {
            let mut k = 0usize;
            let mut t = self.phase_every;
            while t < self.horizon {
                let phase = PHASE_CYCLE[k % PHASE_CYCLE.len()];
                events.push((t, ScenarioEvent::PhaseShift { phase }));
                k += 1;
                t += self.phase_every;
            }
        }

        if let Some(d) = self.diurnal {
            let every = d.every.max(1);
            let mut t = every;
            while t < self.horizon {
                let w = (std::f64::consts::TAU * t as f64 / d.period.max(1) as f64).sin();
                let scale = (1.0 + d.amplitude * w).max(0.1);
                events.push((t, ScenarioEvent::SetLoad { scale }));
                t += every;
            }
        }

        for d in &self.drains {
            events.push((d.at, ScenarioEvent::Drain { server: d.server }));
            if d.recover_at > d.at && d.recover_at < self.horizon {
                events.push((d.recover_at, ScenarioEvent::Recover { server: d.server }));
            }
        }
        for f in &self.fabric {
            events.push((f.at, ScenarioEvent::DegradeFabric { scale: f.scale }));
            if f.restore_at > f.at && f.restore_at < self.horizon {
                events.push((f.restore_at, ScenarioEvent::RestoreFabric));
            }
        }
        for l in &self.link_downs {
            events.push((l.at, ScenarioEvent::LinkDown { a: l.a, b: l.b }));
            if l.restore_at > l.at && l.restore_at < self.horizon {
                events.push((l.restore_at, ScenarioEvent::LinkRestore { a: l.a, b: l.b }));
            }
        }

        for c in &self.crashes {
            events.push((c.at, ScenarioEvent::Crash { server: c.server, rack: c.rack }));
            if c.recover_at > c.at && c.recover_at < self.horizon {
                let ev = ScenarioEvent::CrashRecover { server: c.server, rack: c.rack };
                events.push((c.recover_at, ev));
            }
        }
        // The storm stream (4) forks only when a storm exists: legacy
        // specs draw exactly the streams they always drew, keeping their
        // timelines bit-identical.
        if let Some(s) = self.crash_storm {
            let mut crash_rng = rng.fork(4);
            let span = s.to.saturating_sub(s.from).max(1) as usize;
            for _ in 0..s.count {
                let t = s.from + crash_rng.below(span) as u64;
                let server = crash_rng.below(s.servers.max(1));
                events.push((t, ScenarioEvent::Crash { server, rack: false }));
                let r = t + s.outage;
                if s.outage > 0 && r < self.horizon {
                    events.push((r, ScenarioEvent::CrashRecover { server, rack: false }));
                }
            }
        }

        events.sort_by_key(|(t, _)| *t);
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn churny() -> ScenarioSpec {
        ScenarioSpec {
            name: "churn-test".into(),
            horizon: 200,
            warmup: 40,
            initial: Vec::new(),
            arrive_rate: 0.1,
            depart_rate: 0.05,
            churn_from: 40,
            phase_every: 25,
            diurnal: Some(DiurnalSpec { period: 100, amplitude: 0.5, every: 10 }),
            drains: vec![DrainWindow { at: 80, server: 3, recover_at: 160 }],
            fabric: vec![FabricWindow { at: 50, scale: 0.2, restore_at: 150 }],
            link_downs: vec![LinkWindow { at: 60, a: 0, b: 1, restore_at: 140 }],
            crashes: Vec::new(),
            crash_storm: None,
            admission: false,
            fabric_feedback: false,
        }
    }

    #[test]
    fn timeline_is_deterministic_and_sorted() {
        let spec = churny();
        let a = spec.timeline(42);
        let b = spec.timeline(42);
        assert_eq!(a, b, "same seed must expand identically");
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0), "timeline not sorted");
        assert_ne!(a, spec.timeline(43), "different seed should differ");
    }

    #[test]
    fn timeline_respects_horizon_and_churn_start() {
        let spec = churny();
        for (t, ev) in spec.timeline(7) {
            assert!(t < spec.horizon, "event at {t} past horizon");
            if matches!(ev, ScenarioEvent::Arrive { .. } | ScenarioEvent::Depart) {
                assert!(t >= spec.churn_from, "churn event at {t} before start");
            }
        }
    }

    #[test]
    fn churn_rates_produce_events() {
        let spec = churny();
        let tl = spec.timeline(11);
        let arrivals =
            tl.iter().filter(|(_, e)| matches!(e, ScenarioEvent::Arrive { .. })).count();
        let departs = tl.iter().filter(|(_, e)| matches!(e, ScenarioEvent::Depart)).count();
        // 160 churn-eligible ticks at 0.1/0.05 per tick; this seed's
        // deterministic draw yields 20 arrivals and 2 departures.
        assert!((8..=32).contains(&arrivals), "arrivals {arrivals}");
        assert!(departs >= 1, "departs {departs}");
    }

    #[test]
    fn windows_expand_to_paired_events() {
        let tl = churny().timeline(13);
        assert!(tl.contains(&(80, ScenarioEvent::Drain { server: 3 })));
        assert!(tl.contains(&(160, ScenarioEvent::Recover { server: 3 })));
        assert!(tl.contains(&(50, ScenarioEvent::DegradeFabric { scale: 0.2 })));
        assert!(tl.contains(&(150, ScenarioEvent::RestoreFabric)));
        assert!(tl.contains(&(60, ScenarioEvent::LinkDown { a: 0, b: 1 })));
        assert!(tl.contains(&(140, ScenarioEvent::LinkRestore { a: 0, b: 1 })));
    }

    #[test]
    fn diurnal_scales_stay_positive_and_vary() {
        let tl = churny().timeline(17);
        let scales: Vec<f64> = tl
            .iter()
            .filter_map(|(_, e)| match e {
                ScenarioEvent::SetLoad { scale } => Some(*scale),
                _ => None,
            })
            .collect();
        assert!(scales.len() > 10);
        assert!(scales.iter().all(|&s| s >= 0.1));
        let spread = scales.iter().cloned().fold(f64::MIN, f64::max)
            - scales.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 0.5, "diurnal wave too flat: {spread}");
    }

    #[test]
    fn crash_windows_expand_to_paired_events() {
        let mut spec = churny();
        spec.crashes = vec![
            CrashWindow { at: 70, server: 2, rack: false, recover_at: 120 },
            CrashWindow { at: 90, server: 0, rack: true, recover_at: 0 },
        ];
        let tl = spec.timeline(13);
        assert!(tl.contains(&(70, ScenarioEvent::Crash { server: 2, rack: false })));
        assert!(tl.contains(&(120, ScenarioEvent::CrashRecover { server: 2, rack: false })));
        assert!(tl.contains(&(90, ScenarioEvent::Crash { server: 0, rack: true })));
        let rack_recovers = tl
            .iter()
            .filter(|(_, e)| matches!(e, ScenarioEvent::CrashRecover { rack: true, .. }))
            .count();
        assert_eq!(rack_recovers, 0, "recover_at 0 means no recovery");
    }

    #[test]
    fn crash_storm_is_seeded_bounded_and_leaves_legacy_streams_alone() {
        let mut spec = churny();
        spec.crash_storm =
            Some(CrashStormSpec { from: 50, to: 150, count: 4, servers: 6, outage: 20 });
        let a = spec.timeline(42);
        assert_eq!(a, spec.timeline(42), "storm must be deterministic per seed");
        let crashes: Vec<_> = a
            .iter()
            .filter_map(|(t, e)| match e {
                ScenarioEvent::Crash { server, .. } => Some((*t, *server)),
                _ => None,
            })
            .collect();
        assert_eq!(crashes.len(), 4);
        assert!(crashes.iter().all(|(t, s)| (50..150).contains(t) && *s < 6));
        // The storm draws from its own forked stream: every non-crash
        // event of the legacy expansion is unchanged.
        let legacy = churny().timeline(42);
        let without_crashes: Vec<_> = a
            .iter()
            .filter(|(_, e)| {
                !matches!(e, ScenarioEvent::Crash { .. } | ScenarioEvent::CrashRecover { .. })
            })
            .cloned()
            .collect();
        assert_eq!(without_crashes, legacy, "legacy streams perturbed by the storm fork");
    }

    #[test]
    fn name_salt_separates_scenarios() {
        let mut a = churny();
        let mut b = churny();
        a.name = "alpha".into();
        b.name = "beta".into();
        assert_ne!(a.salted_seed(42), b.salted_seed(42));
    }
}
