//! Drives one simulator (plus optionally the coordinator) through a
//! scenario timeline and aggregates per-scenario metrics.
//!
//! Event application rules (all deterministic):
//! * **Arrive** — coordinator runs place-arrival; if even a reshuffle
//!   finds no online capacity the VM is queued and re-admission is
//!   retried every tick (and naturally succeeds after recovery).  The
//!   vanilla baseline always admits (it overbooks).
//! * **Depart** — the oldest still-running churn VM is destroyed.
//! * **Drain** — [`crate::sim::Simulator::drain_server`] evicts floating
//!   threads; the coordinator then evacuates stranded pinned VMs and
//!   pulls guest memory off the drained nodes through the migration
//!   engine ([`SmMapper::handle_drain`]).
//! * **PhaseShift** — round-robin over running VMs in id order.
//! * **Crash / CrashRecover** — abrupt (possibly rack-correlated) server
//!   loss via [`crate::sim::Simulator::crash_server`]: resident VMs die,
//!   the coordinator attributes the loss, and victims go through the
//!   [`RecoveryOrchestrator`] restart queue (SLO-ordered, exponential
//!   backoff, bounded attempts).  Refused crashes (already down, would
//!   partition the fabric) are logged and skipped — a storm may draw the
//!   same server twice.
//!
//! The reported tail metric follows SLO convention: `p99_tail_rel` is the
//! relative performance of the 99th-percentile *worst* sample — 99% of
//! all (VM, tick) samples in the measurement window perform at least this
//! well.  `ticks_per_sec` is wall clock and is the only field excluded
//! from the determinism contract.

use std::collections::VecDeque;

use anyhow::Result;

use crate::coordinator::{
    AdmissionConfig, AdmissionController, Coordinator, Decision, MapperConfig, RecoveryConfig,
    RecoveryOrchestrator, ShardConfig, ShardedMapper, SmMapper,
};
use crate::experiments::{Algorithm, ScorerChoice};
use crate::runtime::Scorer;
use crate::sim::{SimConfig, Simulator};
use crate::telemetry::{
    self, HealthConfig, HealthEngine, HealthSample, Phase, Recorder, TelemetryConfig, TraceTopo,
};
use crate::topology::{ServerId, Topology};
use crate::util::stats;
use crate::vm::{VmId, VmState, VmType};
use crate::workload::App;

use super::timeline::{ScenarioEvent, ScenarioSpec};

/// Runner configuration shared by every scenario of a suite.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub seed: u64,
    pub scorer: ScorerChoice,
    /// Coordinator override (metric is set per algorithm).
    pub mapper: Option<MapperConfig>,
    /// When set, a flight recorder is installed for the duration of the
    /// run and returned in [`ScenarioResult::telemetry`].  Never affects
    /// simulation outcomes (the recorder only observes).
    pub telemetry: Option<TelemetryConfig>,
    /// Explicit tick-engine override: force the structure-of-arrays
    /// evaluator on/off regardless of the `DVRM_TICK_SOA` env hook.
    /// `None` keeps the [`SimConfig`] default.  Outcomes are identical
    /// either way (the engines are bit-identical); this exists so the
    /// determinism tests can pin the engine without process-global env
    /// writes (tests run concurrently).
    pub tick_soa: Option<bool>,
    /// Explicit worker-thread override for the zone-partitioned parallel
    /// tick (see [`SimConfig::threads`]); `None` keeps the default.
    pub tick_threads: Option<usize>,
    /// Opt-in sharded coordination: `Some(z)` runs every SM algorithm
    /// behind a [`ShardedMapper`] with `z` zones (Z=1 is bit-identical
    /// to the global mapper).  `None` keeps the global [`SmMapper`],
    /// except for [`Algorithm::SmSharded`], which defaults to 4 zones.
    pub shard_zones: Option<usize>,
}

impl ScenarioConfig {
    /// Defaults: native scorer, global mapper, no telemetry, engine and
    /// pool-size hooks untouched.
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            scorer: ScorerChoice::Native,
            mapper: None,
            telemetry: None,
            tick_soa: None,
            tick_threads: None,
            shard_zones: None,
        }
    }
}

/// Deterministic per-scenario aggregate (everything but wall clock).
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioMetrics {
    pub scenario: String,
    pub algorithm: &'static str,
    /// VMs that ran at any point (initial + admitted churn).
    pub vms_seen: usize,
    /// (VM, tick) perf samples in the measurement window.
    pub samples: usize,
    pub mean_rel: f64,
    pub p50_rel: f64,
    /// SLO-style p99 tail: 99% of samples perform at least this well
    /// (the 1st percentile of relative performance).
    pub p99_tail_rel: f64,
    pub remaps: u64,
    /// Worst-first reshuffle passes (arrival-capacity fallback).
    pub reshuffles: u64,
    pub evacuations: u64,
    pub sched_moves: usize,
    pub migrations_started: usize,
    pub gb_moved: f64,
    /// Arrivals queued for lack of capacity.
    pub rejected: u64,
    /// Queued arrivals admitted later (e.g. after recovery).
    pub readmitted: u64,
    /// Fabric link failures + restorations applied (asymmetric-failure
    /// scenarios).
    pub link_events: usize,
    pub events_applied: usize,
    /// Events evicted from the bounded simulator trace (0 unless the
    /// scenario outruns the ring capacity).
    pub trace_dropped: u64,
    // ---- chaos & admission (all zero/1.0 for the legacy scenarios) ----
    /// Servers crashed (each rack member counts once).
    pub crashes: usize,
    /// Crash events refused by the simulator guards (already offline,
    /// would disconnect the fabric, last online server).
    pub crash_refused: usize,
    /// VMs killed by crashes.
    pub vms_killed: usize,
    /// Crash victims successfully restarted.
    pub restarts: u64,
    /// Crash victims lost for good after bounded retries.
    pub permanent_losses: u64,
    /// Restarts that landed past their class SLO.
    pub slo_misses: u64,
    /// Mean kill→running latency over successful restarts, ticks.
    pub mttr_ticks: f64,
    /// p99 kill→running latency, ticks.
    pub p99_restart_ticks: f64,
    /// `1 − lost VM-ticks / offered VM-ticks` (killed-and-waiting or
    /// permanently lost VMs count as lost each tick); 1.0 crash-free.
    pub availability: f64,
    /// Admission-gate decisions (0 unless [`ScenarioSpec::admission`]).
    pub adm_admitted: u64,
    pub adm_rejected: u64,
    pub adm_evicted: u64,
}

/// One scenario run: metrics + the applied-event log (both deterministic)
/// plus wall-clock throughput.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub metrics: ScenarioMetrics,
    pub event_log: Vec<(u64, String)>,
    pub ticks_per_sec: f64,
    /// Flight recorder captured during the run; `Some` iff
    /// [`ScenarioConfig::telemetry`] was set.
    pub telemetry: Option<Recorder>,
}

fn build_scorer(choice: ScorerChoice) -> Scorer {
    match choice {
        ScorerChoice::Auto => Scorer::auto(),
        ScorerChoice::Native => Scorer::Native,
    }
}

/// Admit one VM: (optional) admission gate, create, (coordinator) place,
/// start.  Returns `None` — with the defined VM rolled back — when the
/// gate rejects or placement finds no capacity.
fn admit(
    sim: &mut Simulator,
    mapper: Option<&mut Coordinator>,
    gate: Option<&mut AdmissionController>,
    vm_type: VmType,
    app: App,
) -> Result<Option<VmId>> {
    if let Some(ac) = gate {
        match ac.decide(sim, vm_type) {
            Decision::Admit => {}
            Decision::Reject { .. } => return Ok(None),
            Decision::AdmitAfterEvicting(victims) => {
                for v in victims {
                    sim.destroy(v)?;
                }
            }
        }
    }
    let id = sim.create(vm_type, app);
    if let Some(m) = mapper {
        if m.place_arrival(sim, id).is_err() {
            sim.destroy(id)?;
            return Ok(None);
        }
    }
    sim.start(id)?;
    telemetry::with(|r| {
        r.trace_event(
            sim.tick(),
            id.0,
            "admission.grant",
            None,
            format!("type={};app={app}", vm_type.name()),
        );
    });
    Ok(Some(id))
}

/// Servers hit by a crash event: the named server, or — for a rack
/// crash — every server in the same torus row (the paper topology racks
/// servers along the x dimension).
fn blast_radius(sim: &Simulator, server: usize, rack: bool) -> Vec<usize> {
    if !rack {
        return vec![server];
    }
    let x = sim.topo.spec.torus.0.max(1);
    (0..sim.topo.spec.servers).filter(|s| s / x == server / x).collect()
}

struct EventCtx {
    churn_pool: VecDeque<VmId>,
    pending: VecDeque<(VmType, App)>,
    vms_seen: usize,
    rejected: u64,
    readmitted: u64,
    phase_rr: usize,
    /// Current tick (events need it for restart-latency bookkeeping).
    now: u64,
    /// Headroom gate, installed iff [`ScenarioSpec::admission`].
    admission: Option<AdmissionController>,
    /// Restart queue for crash victims (inert without crashes).
    recovery: RecoveryOrchestrator,
    crashes: usize,
    crash_refused: usize,
    vms_killed: usize,
}

fn apply_event(
    sim: &mut Simulator,
    mapper: &mut Option<Coordinator>,
    ev: &ScenarioEvent,
    ctx: &mut EventCtx,
) -> Result<String> {
    Ok(match ev {
        ScenarioEvent::Arrive { vm_type, app } => {
            match admit(sim, mapper.as_mut(), ctx.admission.as_mut(), *vm_type, *app)? {
                Some(id) => {
                    ctx.churn_pool.push_back(id);
                    ctx.vms_seen += 1;
                    format!("arrive {} {app} -> {id}", vm_type.name())
                }
                None => {
                    ctx.rejected += 1;
                    ctx.pending.push_back((*vm_type, *app));
                    telemetry::with(|r| {
                        r.trace_event(
                            ctx.now,
                            telemetry::CLUSTER_TRACE,
                            "admission.enqueue",
                            None,
                            format!("type={};app={app}", vm_type.name()),
                        );
                    });
                    format!("arrive {} {app} -> queued (no capacity)", vm_type.name())
                }
            }
        }
        ScenarioEvent::Depart => loop {
            match ctx.churn_pool.pop_front() {
                Some(id) if sim.get(id).is_some() => {
                    sim.destroy(id)?;
                    break format!("depart {id}");
                }
                Some(_) => continue, // already gone; try the next oldest
                None => break "depart (no churn vm alive)".to_string(),
            }
        },
        ScenarioEvent::PhaseShift { phase } => {
            let ids: Vec<VmId> = sim
                .vms()
                .filter(|(_, m)| m.vm.state == VmState::Running)
                .map(|(id, _)| *id)
                .collect();
            if ids.is_empty() {
                "phase-shift (no running vm)".to_string()
            } else {
                let id = ids[ctx.phase_rr % ids.len()];
                ctx.phase_rr += 1;
                sim.shift_phase(id, *phase)?;
                format!("phase-shift {id} -> {phase}")
            }
        }
        ScenarioEvent::SetLoad { scale } => {
            sim.set_global_load(*scale)?;
            format!("set-load {scale:.3}")
        }
        ScenarioEvent::Drain { server } => {
            let stranded = sim.drain_server(ServerId(*server))?;
            let failed = match mapper.as_mut() {
                Some(m) => m.handle_drain(sim, ServerId(*server), &stranded)?,
                None => Vec::new(),
            };
            format!("drain s{server} (stranded {}, unplaceable {})", stranded.len(), failed.len())
        }
        ScenarioEvent::Recover { server } => {
            sim.recover_server(ServerId(*server))?;
            format!("recover s{server}")
        }
        ScenarioEvent::DegradeFabric { scale } => {
            sim.degrade_fabric(*scale)?;
            format!("degrade-fabric {scale:.2}")
        }
        ScenarioEvent::RestoreFabric => {
            sim.restore_fabric();
            "restore-fabric".to_string()
        }
        ScenarioEvent::LinkDown { a, b } => {
            sim.fail_fabric_link(ServerId(*a), ServerId(*b))?;
            format!("link-down s{a}<->s{b}")
        }
        ScenarioEvent::LinkRestore { a, b } => {
            sim.restore_fabric_link(ServerId(*a), ServerId(*b))?;
            format!("link-restore s{a}<->s{b}")
        }
        ScenarioEvent::Crash { server, rack } => {
            let members = blast_radius(sim, *server, *rack);
            let (mut down, mut refused, mut killed_total) = (0usize, 0usize, 0usize);
            for s in members {
                // Snapshot classes first: the crash removes its victims,
                // and the restart queue needs their (type, app).
                let classes: std::collections::BTreeMap<VmId, (VmType, App)> =
                    sim.vms().map(|(id, m)| (*id, (m.vm.vm_type, m.vm.app))).collect();
                // Refusals (already offline, would disconnect the fabric,
                // last online server) are survivable by design: a storm
                // may draw the same server twice.
                match sim.crash_server(ServerId(s)) {
                    Ok(killed) => {
                        down += 1;
                        killed_total += killed.len();
                        for id in &killed {
                            if let Some((vm_type, app)) = classes.get(id) {
                                ctx.recovery.on_kill(*id, *vm_type, *app, ctx.now);
                            }
                        }
                        if let Some(m) = mapper.as_mut() {
                            m.handle_crash(sim, &killed)?;
                        }
                    }
                    Err(_) => refused += 1,
                }
            }
            ctx.crashes += down;
            ctx.crash_refused += refused;
            ctx.vms_killed += killed_total;
            format!(
                "crash s{server}{} (down {down}, refused {refused}, killed {killed_total})",
                if *rack { " rack" } else { "" }
            )
        }
        ScenarioEvent::CrashRecover { server, rack } => {
            let members = blast_radius(sim, *server, *rack);
            let mut back = 0usize;
            for s in members {
                if sim.is_server_crashed(ServerId(s)) && sim.recover_server(ServerId(s)).is_ok() {
                    back += 1;
                }
            }
            format!("crash-recover s{server}{} ({back} back)", if *rack { " rack" } else { "" })
        }
    })
}

/// Run one scenario under one algorithm.
pub fn run_scenario(
    spec: &ScenarioSpec,
    alg: Algorithm,
    cfg: &ScenarioConfig,
) -> Result<ScenarioResult> {
    let sim_seed = spec.salted_seed(cfg.seed);
    // The recorder lives on this thread for the whole run; the guard
    // uninstalls it on every exit path (including `?` early returns).
    let guard = cfg.telemetry.clone().map(|t| telemetry::install(Recorder::new(t)));
    let mut sim_cfg = match alg {
        Algorithm::Vanilla => SimConfig::vanilla(sim_seed),
        Algorithm::AutoNuma => SimConfig::vanilla_autonuma(sim_seed),
        _ => SimConfig::pinned(sim_seed),
    };
    // Legacy scenarios keep feedback off (bit-identical to pre-fabric
    // runs); link-failure scenarios turn the congestion ledger on.
    sim_cfg.fabric.feedback = spec.fabric_feedback;
    if let Some(soa) = cfg.tick_soa {
        sim_cfg.soa = soa;
    }
    if let Some(threads) = cfg.tick_threads {
        sim_cfg.threads = threads;
    }
    let mut sim = Simulator::new(Topology::paper(), sim_cfg);
    let zones = cfg
        .shard_zones
        .or((alg == Algorithm::SmSharded).then_some(4))
        .filter(|z| *z > 0);
    let mut mapper = alg.metric().map(|metric| {
        let mcfg = cfg.mapper.clone().unwrap_or_else(|| MapperConfig::new(metric));
        let mcfg = MapperConfig { metric, ..mcfg };
        let scorer = build_scorer(cfg.scorer);
        match zones {
            Some(z) => Coordinator::Sharded(ShardedMapper::new(
                mcfg,
                scorer,
                ShardConfig::new(z),
                &sim.topo,
            )),
            None => Coordinator::Global(SmMapper::new(mcfg, scorer)),
        }
    });
    // Topology context for zone/rack attribution (trace + localization),
    // and the streaming watchdog when the recorder asks for it.  Both
    // only *observe* deterministic values on this (serial) thread, so
    // the bit-identical-output contract holds with them on or off.
    let topo_ctx = TraceTopo {
        servers: sim.topo.spec.servers,
        torus_x: sim.topo.spec.torus.0.max(1),
        zones: zones.unwrap_or(1),
    };
    telemetry::with(|r| r.set_topology(topo_ctx));
    let mut health = telemetry::with_ret(|r| r.health_enabled())
        .unwrap_or(false)
        .then(|| HealthEngine::new(HealthConfig::default(), topo_ctx));
    let mut trace_cursor: u64 = 0;

    let timeline = spec.timeline(cfg.seed);
    let mut initial = spec.initial.clone();
    initial.sort_by_key(|a| a.at_tick);

    let mut cursor = 0usize;
    let mut init_cursor = 0usize;
    let mut ctx = EventCtx {
        churn_pool: VecDeque::new(),
        pending: VecDeque::new(),
        vms_seen: 0,
        rejected: 0,
        readmitted: 0,
        phase_rr: 0,
        now: 0,
        admission: spec.admission.then(|| AdmissionController::new(AdmissionConfig::default())),
        recovery: RecoveryOrchestrator::new(RecoveryConfig::default(), sim_seed),
        crashes: 0,
        crash_refused: 0,
        vms_killed: 0,
    };
    let mut samples: Vec<f64> = Vec::new();
    let mut event_log: Vec<(u64, String)> = Vec::new();
    let (mut offered_ticks, mut lost_ticks) = (0u64, 0u64);

    let t0 = std::time::Instant::now();
    for t in 0..spec.horizon {
        ctx.now = t;
        while init_cursor < initial.len() && initial[init_cursor].at_tick <= t {
            let a = initial[init_cursor];
            init_cursor += 1;
            match admit(&mut sim, mapper.as_mut(), ctx.admission.as_mut(), a.vm_type, a.app)? {
                Some(_) => ctx.vms_seen += 1,
                None => {
                    ctx.rejected += 1;
                    ctx.pending.push_back((a.vm_type, a.app));
                    telemetry::with(|r| {
                        r.trace_event(
                            t,
                            telemetry::CLUSTER_TRACE,
                            "admission.enqueue",
                            None,
                            format!("type={};app={}", a.vm_type.name(), a.app),
                        );
                    });
                }
            }
        }
        while cursor < timeline.len() && timeline[cursor].0 <= t {
            let ev = timeline[cursor].1.clone();
            cursor += 1;
            let span = telemetry::span(Phase::ScenarioEvent);
            let desc = apply_event(&mut sim, &mut mapper, &ev, &mut ctx)?;
            drop(span);
            event_log.push((t, desc));
        }
        // Restart drive: re-place crash victims in SLO order.  The
        // orchestrator is a coordinator service, so coordinated runs pump
        // it every tick (restart latency IS the SLO; the backoff gates
        // keep a shortage from hammering place_arrival).  The kernel
        // baseline has no such service — its victims wait for the same
        // slow poll the re-admission queue uses, which is exactly the
        // MTTR gap EXP-FAULT measures.  Failures requeue with backoff
        // until the attempt bound declares them permanently lost.
        while mapper.is_some() || t % 5 == 0 {
            let Some(e) = ctx.recovery.pop_due(t) else { break };
            match admit(&mut sim, mapper.as_mut(), ctx.admission.as_mut(), e.vm_type, e.app)? {
                Some(id) => {
                    ctx.recovery.on_restarted(&e, t);
                    ctx.vms_seen += 1;
                    // The restart closes the *old* VM's recovery span;
                    // `new=` links it to the replacement's trace.
                    telemetry::with(|r| {
                        r.trace_event(
                            t,
                            e.vm.0,
                            "restart.ok",
                            None,
                            format!("new={};latency={}", id.0, t.saturating_sub(e.killed_at)),
                        );
                    });
                    event_log.push((
                        t,
                        format!(
                            "restart {} {} -> {id} (latency {})",
                            e.vm_type.name(),
                            e.app,
                            t.saturating_sub(e.killed_at)
                        ),
                    ));
                }
                None => {
                    let attempt = e.attempts + 1;
                    let lost = attempt >= ctx.recovery.cfg.max_attempts;
                    let vm = e.vm.0;
                    ctx.recovery.on_retry_failed(e, t);
                    telemetry::with(|r| {
                        if lost {
                            r.trace_event(t, vm, "restart.lost", None, format!("attempts={attempt}"));
                        } else {
                            r.trace_event(t, vm, "restart.retry", None, format!("attempt={attempt}"));
                        }
                    });
                }
            }
        }
        // Re-admission: drain the queue while capacity allows (recovered
        // servers or departures free slots up).  Throttled to every 5th
        // tick: a failed place_arrival can fall back to a whole-cluster
        // reshuffle, which must not run on every tick of a long shortage.
        while t % 5 == 0 {
            let Some((vm_type, app)) = ctx.pending.front().copied() else { break };
            match admit(&mut sim, mapper.as_mut(), ctx.admission.as_mut(), vm_type, app)? {
                Some(id) => {
                    ctx.pending.pop_front();
                    ctx.churn_pool.push_back(id);
                    ctx.vms_seen += 1;
                    ctx.readmitted += 1;
                    telemetry::with(|r| {
                        r.trace_event(
                            t,
                            id.0,
                            "admission.readmit",
                            None,
                            format!("type={};app={app}", vm_type.name()),
                        );
                    });
                    event_log.push((t, format!("re-admit {} {app} -> {id}", vm_type.name())));
                }
                None => break,
            }
        }

        let out = sim.step();
        // Availability ledger: every killed-and-not-yet-restarted VM (and
        // every permanent loss) is a lost VM-tick that the cluster was
        // asked to serve.  Crash-free runs never increment `lost_ticks`.
        let waiting = ctx.recovery.outstanding() as u64 + ctx.recovery.stats.permanent_losses;
        offered_ticks += out.len() as u64 + waiting;
        lost_ticks += waiting;
        if t >= spec.warmup {
            for (_, s) in &out {
                samples.push(s.rel_perf);
            }
        }
        // The mapper's persistent DeltaProblem carries over between
        // monitoring passes (and arrivals/drains above): each interval
        // patches only the rows the simulator dirtied since the last
        // decision instead of rebuilding the scoring problem.
        if let Some(m) = mapper.as_mut() {
            if t % m.interval_every() == 0 {
                m.interval(&mut sim)?;
            }
        }
        // Streaming watchdog: one deterministic step over this tick's
        // burn-rate signals plus the trace events emitted since the last
        // step.  Alerts land in the recorder (store + JSONL).
        if let Some(h) = health.as_mut() {
            let (new_events, cur) = telemetry::with_ret(|r| {
                let log = r.trace_log();
                (log.events_since(trace_cursor), log.cursor())
            })
            .unwrap_or((Vec::new(), trace_cursor));
            trace_cursor = cur;
            let mean_rel = if out.is_empty() {
                f64::NAN
            } else {
                out.iter().map(|(_, s)| s.rel_perf).sum::<f64>() / out.len() as f64
            };
            let rho_max = sim.link_utilization().into_iter().fold(0.0f64, f64::max);
            let sample = HealthSample {
                lost_ticks: waiting,
                offered_ticks: out.len() as u64 + waiting,
                mean_rel,
                rho_max,
                slo_misses: ctx.recovery.stats.slo_misses,
                permanent_losses: ctx.recovery.stats.permanent_losses,
                queue_depth: ctx.pending.len(),
                outstanding_restarts: ctx.recovery.outstanding(),
            };
            let alerts = h.observe_tick(t, &sample, &new_events);
            if !alerts.is_empty() {
                telemetry::with(|r| {
                    for a in alerts {
                        r.push_alert(a);
                    }
                });
            }
        }
        telemetry::with(|r| r.tick_sample(t));
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);

    let (remaps, reshuffles, evacuations) = match &mapper {
        Some(m) => {
            let s = m.stats();
            (s.remaps, s.reshuffles, s.evacuations)
        }
        None => (0, 0, 0),
    };
    let (adm_admitted, adm_rejected, adm_evicted) = match &ctx.admission {
        Some(ac) => (ac.admitted, ac.rejected, ac.evictions),
        None => (0, 0, 0),
    };
    let rec = ctx.recovery.stats.clone();
    let (alerts_total, alerts_firing) = health
        .as_ref()
        .map(|h| (h.records().len() as u64, h.firing_count()))
        .unwrap_or((0, 0));
    telemetry::with(|r| {
        let reg = r.registry_mut();
        reg.add_counter("health.alerts.total", alerts_total as f64);
        reg.add_counter("health.alerts.firing", alerts_firing as f64);
        reg.add_counter("chaos.crashes", ctx.crashes as f64);
        reg.add_counter("chaos.vms_killed", ctx.vms_killed as f64);
        reg.add_counter("chaos.restarts", rec.restarts as f64);
        reg.add_counter("chaos.permanent_losses", rec.permanent_losses as f64);
        reg.add_counter("chaos.slo_misses", rec.slo_misses as f64);
        reg.add_counter("admission.admitted", adm_admitted as f64);
        reg.add_counter("admission.rejected", adm_rejected as f64);
        reg.add_counter("admission.evicted", adm_evicted as f64);
    });
    let metrics = ScenarioMetrics {
        scenario: spec.name.clone(),
        algorithm: alg.name(),
        vms_seen: ctx.vms_seen,
        samples: samples.len(),
        mean_rel: stats::mean(&samples),
        p50_rel: if samples.is_empty() { 0.0 } else { stats::percentile(&samples, 50.0) },
        p99_tail_rel: if samples.is_empty() { 0.0 } else { stats::percentile(&samples, 1.0) },
        remaps,
        reshuffles,
        evacuations,
        sched_moves: sim.trace.total_sched_moves(),
        migrations_started: sim.trace.count_kind("mem_migration_started"),
        gb_moved: sim.trace.total_gb_migrated(),
        rejected: ctx.rejected,
        readmitted: ctx.readmitted,
        link_events: sim.trace.count_kind("fabric_link_down")
            + sim.trace.count_kind("fabric_link_restored"),
        events_applied: event_log.len(),
        trace_dropped: sim.trace.dropped(),
        crashes: ctx.crashes,
        crash_refused: ctx.crash_refused,
        vms_killed: ctx.vms_killed,
        restarts: rec.restarts,
        permanent_losses: rec.permanent_losses,
        slo_misses: rec.slo_misses,
        mttr_ticks: rec.mttr(),
        p99_restart_ticks: rec.p99_restart(),
        availability: if offered_ticks == 0 {
            1.0
        } else {
            1.0 - lost_ticks as f64 / offered_ticks as f64
        },
        adm_admitted,
        adm_rejected,
        adm_evicted,
    };
    let telemetry = guard.and_then(|g| g.finish()).map(|mut rec| {
        rec.push_spans_summary();
        rec
    });
    Ok(ScenarioResult { metrics, event_log, ticks_per_sec: spec.horizon as f64 / wall, telemetry })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::suite;

    #[test]
    fn steady_scenario_collects_samples_for_both_algorithms() {
        let spec = suite::named("steady", true).unwrap();
        let cfg = ScenarioConfig::new(1);
        for alg in [Algorithm::Vanilla, Algorithm::SmIpc] {
            let r = run_scenario(&spec, alg, &cfg).unwrap();
            assert!(r.metrics.samples > 100, "{alg:?}: {} samples", r.metrics.samples);
            assert_eq!(r.metrics.vms_seen, spec.initial.len());
            assert!(r.metrics.mean_rel > 0.0);
            assert!(r.metrics.p99_tail_rel <= r.metrics.p50_rel);
            assert_eq!(r.metrics.rejected, 0, "steady load must fit");
        }
    }

    #[test]
    fn churn_scenario_arrives_and_departs() {
        let spec = suite::named("churn", true).unwrap();
        let r = run_scenario(&spec, Algorithm::SmIpc, &ScenarioConfig::new(2)).unwrap();
        assert!(
            r.metrics.vms_seen > spec.initial.len(),
            "churn must admit extra VMs: {}",
            r.metrics.vms_seen
        );
        assert!(r.event_log.iter().any(|(_, d)| d.starts_with("arrive")));
        assert!(r.event_log.iter().any(|(_, d)| d.starts_with("depart")));
    }

    #[test]
    fn crash_single_kills_restarts_and_degrades_availability() {
        let spec = suite::named("crash-single", true).unwrap();
        let r = run_scenario(&spec, Algorithm::SmIpc, &ScenarioConfig::new(7)).unwrap();
        let m = &r.metrics;
        assert_eq!(m.crashes, 1, "one crash window: {:?}", r.event_log);
        assert!(r.event_log.iter().any(|(_, d)| d.starts_with("crash s4")));
        assert!(r.event_log.iter().any(|(_, d)| d.starts_with("crash-recover s4 (1 back)")));
        if m.vms_killed > 0 {
            // Victims wait at least one tick, so availability must dip.
            assert!(m.availability < 1.0, "availability {}", m.availability);
            assert!(
                m.restarts + m.permanent_losses <= m.vms_killed as u64,
                "{} restarts + {} losses vs {} killed",
                m.restarts,
                m.permanent_losses,
                m.vms_killed
            );
            if m.restarts > 0 {
                assert!(m.mttr_ticks > 0.0 && m.p99_restart_ticks >= m.mttr_ticks);
                assert!(r.event_log.iter().any(|(_, d)| d.starts_with("restart")));
            }
        }
        assert!(m.availability <= 1.0 && m.availability > 0.0);
        assert!(m.adm_admitted > 0, "the gate must have admitted the base population");
    }

    #[test]
    fn rack_crash_downs_the_row_and_storm_is_deterministic() {
        let spec = suite::named("crash-rack", true).unwrap();
        let r = run_scenario(&spec, Algorithm::SmIpc, &ScenarioConfig::new(11)).unwrap();
        // Rack of server 3 on the (3,2) torus = the whole row {3,4,5}.
        assert!(
            r.event_log.iter().any(|(_, d)| d.starts_with("crash s3 rack (down 3")),
            "rack crash must down all three row members: {:?}",
            r.event_log
        );
        assert_eq!(r.metrics.crashes, 3);

        let spec = suite::named("crash-storm", true).unwrap();
        let a = run_scenario(&spec, Algorithm::SmIpc, &ScenarioConfig::new(11)).unwrap();
        let b = run_scenario(&spec, Algorithm::SmIpc, &ScenarioConfig::new(11)).unwrap();
        assert_eq!(a.metrics, b.metrics, "chaos must be deterministic per seed");
        assert_eq!(a.event_log, b.event_log);
        assert!(a.metrics.crashes + a.metrics.crash_refused >= 1, "storm must attempt crashes");
    }

    #[test]
    fn drain_scenario_logs_drain_and_recovery() {
        let spec = suite::named("drain", true).unwrap();
        let r = run_scenario(&spec, Algorithm::SmIpc, &ScenarioConfig::new(3)).unwrap();
        let drain_line = r
            .event_log
            .iter()
            .find(|(_, d)| d.starts_with("drain s4"))
            .unwrap_or_else(|| panic!("no drain logged: {:?}", r.event_log))
            .1
            .clone();
        assert!(r.event_log.iter().any(|(_, d)| d.starts_with("recover s4")));
        // If anything was pinned there, the coordinator must have moved it
        // (and its memory) off the drained server.
        if !drain_line.contains("stranded 0") {
            assert!(r.metrics.evacuations > 0, "{drain_line}: no evacuation");
            assert!(r.metrics.gb_moved > 0.0, "{drain_line}: no memory evacuated");
        }
    }
}
