//! Dynamic scenario engine: declarative, seed-deterministic timelines of
//! cluster events — VM arrival/departure churn (Poisson), per-app phase
//! shifts, diurnal load multipliers, server drain/recovery, fabric-link
//! degradation — applied to the [`crate::sim::Simulator`] between mapper
//! intervals.
//!
//! The paper evaluates mapping quality under *live* conditions; the static
//! harness ([`crate::experiments::harness`]) only replays arrival traces
//! to steady state.  This module is the stress layer on top: a
//! [`ScenarioSpec`] expands into a timeline of [`ScenarioEvent`]s, the
//! [`runner`] drives a simulator (plus optionally the coordinator) through
//! it, and [`suite`] packages the five named scenarios (steady, churn,
//! drain, diurnal, degraded-fabric) compared across `LinuxSched` vs the
//! coordinator, with per-scenario JSON for the CI artifact.
//!
//! **Determinism contract**: the same `(spec, algorithm, seed)` produces a
//! bit-identical event log and final metrics — across runs and across
//! thread-pool sizes (`run_suite_on`); only `ticks_per_sec` (wall clock)
//! is excluded.  Property-tested in `tests/scenarios.rs`.

// Not yet swept for full rustdoc coverage -- the crate-level
// `#![warn(missing_docs)]` allow-list (see ARCHITECTURE.md
// §Documentation).
#![allow(missing_docs)]

pub mod runner;
pub mod suite;
pub mod timeline;

pub use runner::{run_scenario, ScenarioConfig, ScenarioMetrics, ScenarioResult};
pub use suite::{
    chaos_suite, full_suite, run_suite, run_suite_on, smoke_suite, to_json, CHAOS_SCENARIO_NAMES,
    SCENARIO_NAMES,
};
pub use timeline::{
    CrashStormSpec, CrashWindow, DiurnalSpec, DrainWindow, FabricWindow, LinkWindow, ScenarioEvent,
    ScenarioSpec,
};
