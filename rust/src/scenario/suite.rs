//! The named scenario suite (steady, churn, drain, diurnal,
//! degraded-fabric), run for `LinuxSched` (vanilla) vs the coordinator
//! (SM-IPC) with per-scenario JSON output — the payload behind
//! `dvrm scenarios --suite smoke|full` and the CI `scenario-smoke` job.

use anyhow::{bail, Result};

use crate::experiments::figures::Output;
use crate::experiments::{Algorithm, ExpOptions};
use crate::util::pool::{self, ThreadPool};
use crate::util::table::Table;
use crate::vm::VmType;
use crate::workload::trace::Arrival;
use crate::workload::App;

use super::runner::{run_scenario, ScenarioConfig, ScenarioResult};
use super::timeline::{
    CrashStormSpec, CrashWindow, DiurnalSpec, DrainWindow, FabricWindow, LinkWindow, ScenarioSpec,
};

/// The compared policies: the kernel baseline ("LinuxSched") and the
/// coordinator (SM-IPC).
pub const SUITE_ALGS: [Algorithm; 2] = [Algorithm::Vanilla, Algorithm::SmIpc];

/// The six named scenarios.
pub const SCENARIO_NAMES: [&str; 6] =
    ["steady", "churn", "drain", "diurnal", "degraded-fabric", "degraded-link"];

/// The chaos scenarios (crash-failure injection; `dvrm scenarios --suite
/// chaos` and EXP-FAULT).  Kept out of [`SCENARIO_NAMES`] so the legacy
/// suite stays bit-identical.
pub const CHAOS_SCENARIO_NAMES: [&str; 3] = ["crash-single", "crash-rack", "crash-storm"];

/// Steady background population: ~48 vCPUs (1/6 of the paper machine) of
/// mixed classes, leaving headroom for churn, drains and re-admission.
fn base_population() -> Vec<Arrival> {
    let medium = [App::Stream, App::Derby];
    let small = [
        App::Sockshop,
        App::Mpegaudio,
        App::Fft,
        App::Sunflow,
        App::Sor,
        App::Sockshop,
        App::Neo4j,
        App::Derby,
    ];
    let mut out = Vec::new();
    for (i, app) in medium.iter().enumerate() {
        out.push(Arrival { at_tick: i as u64 * 2, vm_type: VmType::Medium, app: *app });
    }
    for (i, app) in small.iter().enumerate() {
        out.push(Arrival { at_tick: 4 + i as u64 * 2, vm_type: VmType::Small, app: *app });
    }
    out
}

/// Build one named scenario.  `fast` shrinks the horizon for CI smoke.
pub fn named(name: &str, fast: bool) -> Option<ScenarioSpec> {
    let h: u64 = if fast { 140 } else { 600 };
    let mut s = ScenarioSpec {
        name: name.to_string(),
        horizon: h,
        warmup: h / 5,
        initial: base_population(),
        arrive_rate: 0.0,
        depart_rate: 0.0,
        churn_from: h / 5,
        phase_every: 0,
        diurnal: None,
        drains: Vec::new(),
        fabric: Vec::new(),
        link_downs: Vec::new(),
        crashes: Vec::new(),
        crash_storm: None,
        admission: false,
        fabric_feedback: false,
    };
    match name {
        "steady" => {}
        "churn" => {
            s.arrive_rate = 16.0 / h as f64;
            s.depart_rate = 12.0 / h as f64;
        }
        "drain" => {
            s.drains = vec![DrainWindow { at: h * 2 / 5, server: 4, recover_at: h * 4 / 5 }];
        }
        "diurnal" => {
            s.diurnal =
                Some(DiurnalSpec { period: h / 2, amplitude: 0.5, every: (h / 24).max(1) });
            s.phase_every = h / 8;
        }
        "degraded-fabric" => {
            s.fabric = vec![FabricWindow { at: h / 4, scale: 0.1, restore_at: h * 3 / 4 }];
            s.arrive_rate = 6.0 / h as f64;
            s.depart_rate = 4.0 / h as f64;
        }
        "crash-single" => {
            // One abrupt server loss mid-run, repaired later — the
            // minimal MTTR / availability measurement.  Light churn keeps
            // arrivals flowing through the admission gate during the
            // outage.
            s.crashes =
                vec![CrashWindow { at: h * 2 / 5, server: 4, rack: false, recover_at: h * 4 / 5 }];
            s.admission = true;
            s.arrive_rate = 6.0 / h as f64;
            s.depart_rate = 4.0 / h as f64;
        }
        "crash-rack" => {
            // Correlated failure: the whole torus row of server 3 dies at
            // once (half the machine), then comes back.  The survivors
            // must absorb every restart.
            s.crashes =
                vec![CrashWindow { at: h * 2 / 5, server: 3, rack: true, recover_at: h * 7 / 10 }];
            s.admission = true;
        }
        "crash-storm" => {
            // Seed-randomized storm: repeated crashes with short outages,
            // some drawn on already-dead servers (refused, by design).
            s.crash_storm = Some(CrashStormSpec {
                from: h / 5,
                to: h * 4 / 5,
                count: 5,
                servers: 6,
                outage: h / 10,
            });
            s.admission = true;
            s.arrive_rate = 6.0 / h as f64;
            s.depart_rate = 4.0 / h as f64;
        }
        "degraded-link" => {
            // Asymmetric failure: one torus link dies mid-run; traffic
            // between servers 0 and 1 detours and contends with what is
            // already on the surviving links.  Congestion feedback is on —
            // this is the scenario the fabric ledger exists for — plus
            // churn and phase shifts so mapping decisions happen while
            // the link is out.
            s.link_downs = vec![LinkWindow { at: h / 4, a: 0, b: 1, restore_at: h * 3 / 4 }];
            s.fabric_feedback = true;
            s.arrive_rate = 8.0 / h as f64;
            s.depart_rate = 6.0 / h as f64;
            s.phase_every = h / 10;
        }
        _ => return None,
    }
    Some(s)
}

fn suite(fast: bool) -> Vec<ScenarioSpec> {
    SCENARIO_NAMES.iter().map(|n| named(n, fast).expect("known scenario")).collect()
}

/// Small topology-of-time suite for CI (short horizon).
pub fn smoke_suite() -> Vec<ScenarioSpec> {
    suite(true)
}

/// Full-length suite.
pub fn full_suite() -> Vec<ScenarioSpec> {
    suite(false)
}

/// The crash-failure suite (short horizon — CI `chaos-smoke` and tests).
pub fn chaos_suite(fast: bool) -> Vec<ScenarioSpec> {
    CHAOS_SCENARIO_NAMES.iter().map(|n| named(n, fast).expect("known scenario")).collect()
}

/// Run `specs × {LinuxSched, SM-IPC}` on the shared pool, in order:
/// `[s0×vanilla, s0×sm, s1×vanilla, ...]`.
pub fn run_suite(specs: &[ScenarioSpec], cfg: &ScenarioConfig) -> Result<Vec<ScenarioResult>> {
    run_suite_on(pool::global(), specs, cfg)
}

/// [`run_suite`] on an explicit pool.  Each job owns its simulator and
/// RNG streams, so results are bit-identical across pool sizes (only
/// `ticks_per_sec` varies) — property-tested in `tests/scenarios.rs`.
pub fn run_suite_on(
    pool: &ThreadPool,
    specs: &[ScenarioSpec],
    cfg: &ScenarioConfig,
) -> Result<Vec<ScenarioResult>> {
    let jobs: Vec<(ScenarioSpec, Algorithm, ScenarioConfig)> = specs
        .iter()
        .flat_map(|s| SUITE_ALGS.iter().map(move |a| (s.clone(), *a, cfg.clone())))
        .collect();
    if jobs.len() <= 1 {
        return jobs.into_iter().map(|(s, a, c)| run_scenario(&s, a, &c)).collect();
    }
    pool.scope_map(jobs, |(s, a, c)| run_scenario(&s, a, &c)).into_iter().collect()
}

/// Hand-rolled JSON export (no serde offline) — one record per
/// (scenario, algorithm); the CI artifact.
pub fn to_json(results: &[ScenarioResult]) -> String {
    let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
    let mut out = String::from("{\n  \"scenarios\": [\n");
    for (k, r) in results.iter().enumerate() {
        let m = &r.metrics;
        out.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"algorithm\": \"{}\", \"vms\": {}, \
             \"samples\": {}, \"mean_rel\": {:.6}, \"p50_rel\": {:.6}, \
             \"p99_tail_rel\": {:.6}, \"remaps\": {}, \"reshuffles\": {}, \
             \"evacuations\": {}, \
             \"sched_moves\": {}, \"migrations_started\": {}, \"gb_moved\": {:.3}, \
             \"rejected\": {}, \"readmitted\": {}, \"link_events\": {}, \"events\": {}, \
             \"trace_dropped\": {}, \
             \"crashes\": {}, \"vms_killed\": {}, \"restarts\": {}, \
             \"permanent_losses\": {}, \"slo_misses\": {}, \"mttr_ticks\": {:.3}, \
             \"p99_restart_ticks\": {:.3}, \"availability\": {:.6}, \
             \"adm_admitted\": {}, \"adm_rejected\": {}, \"adm_evicted\": {}, \
             \"ticks_per_sec\": {:.1}}}{}\n",
            esc(&m.scenario),
            esc(m.algorithm),
            m.vms_seen,
            m.samples,
            m.mean_rel,
            m.p50_rel,
            m.p99_tail_rel,
            m.remaps,
            m.reshuffles,
            m.evacuations,
            m.sched_moves,
            m.migrations_started,
            m.gb_moved,
            m.rejected,
            m.readmitted,
            m.link_events,
            m.events_applied,
            m.trace_dropped,
            m.crashes,
            m.vms_killed,
            m.restarts,
            m.permanent_losses,
            m.slo_misses,
            m.mttr_ticks,
            m.p99_restart_ticks,
            m.availability,
            m.adm_admitted,
            m.adm_rejected,
            m.adm_evicted,
            r.ticks_per_sec,
            if k + 1 == results.len() { "" } else { "," },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Render suite results as the `scenarios` experiment table.
pub fn render_table(results: &[ScenarioResult]) -> Table {
    let mut t = Table::new("EXP-SCEN: dynamic scenarios — LinuxSched vs coordinator").header(&[
        "scenario",
        "algorithm",
        "p50 rel",
        "p99-tail rel",
        "mean rel",
        "remaps",
        "migs",
        "GB moved",
        "rejected",
        "ticks/s",
    ]);
    for r in results {
        let m = &r.metrics;
        t.row(vec![
            m.scenario.clone(),
            m.algorithm.to_string(),
            format!("{:.3}", m.p50_rel),
            format!("{:.3}", m.p99_tail_rel),
            format!("{:.3}", m.mean_rel),
            m.remaps.to_string(),
            m.migrations_started.to_string(),
            format!("{:.1}", m.gb_moved),
            m.rejected.to_string(),
            format!("{:.0}", r.ticks_per_sec),
        ]);
    }
    t
}

/// The `scenarios` experiment (`dvrm experiment scenarios`).
pub fn experiment(o: &ExpOptions) -> Result<Output> {
    let specs = if o.fast { smoke_suite() } else { full_suite() };
    let cfg = ScenarioConfig { scorer: o.scorer, ..ScenarioConfig::new(o.seed) };
    let results = run_suite(&specs, &cfg)?;
    let t = render_table(&results);
    Ok(Output { text: t.render(), tables: vec![("scenarios".into(), t)] })
}

/// Resolve a suite by CLI name.
pub fn suite_by_name(name: &str) -> Result<Vec<ScenarioSpec>> {
    match name {
        "smoke" => Ok(smoke_suite()),
        "full" => Ok(full_suite()),
        "chaos" => Ok(chaos_suite(true)),
        "chaos-full" => Ok(chaos_suite(false)),
        other => bail!("unknown suite {other:?}; known: smoke, full, chaos, chaos-full"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_the_six_named_scenarios() {
        let s = smoke_suite();
        assert_eq!(s.len(), 6);
        for (spec, name) in s.iter().zip(SCENARIO_NAMES.iter()) {
            assert_eq!(spec.name, *name);
            assert!(spec.warmup < spec.horizon);
        }
        assert!(named("nosuch", true).is_none());
        assert!(suite_by_name("nosuch").is_err());
    }

    #[test]
    fn chaos_is_opt_in_and_legacy_specs_stay_clean() {
        for name in SCENARIO_NAMES {
            let s = named(name, true).unwrap();
            assert!(s.crashes.is_empty(), "{name} must not crash");
            assert!(s.crash_storm.is_none(), "{name} must not storm");
            assert!(!s.admission, "{name} must bypass the gate");
        }
        let c = chaos_suite(true);
        assert_eq!(c.len(), CHAOS_SCENARIO_NAMES.len());
        for s in &c {
            assert!(s.admission, "{}: chaos runs gate arrivals", s.name);
            assert!(
                !s.crashes.is_empty() || s.crash_storm.is_some(),
                "{}: chaos runs must crash something",
                s.name
            );
        }
        assert!(suite_by_name("chaos").is_ok());
        assert!(suite_by_name("chaos-full").is_ok());
    }

    #[test]
    fn base_population_fits_comfortably() {
        let vcpus: usize = base_population().iter().map(|a| a.vm_type.spec().vcpus).sum();
        assert!(vcpus <= 64, "background too heavy: {vcpus} vcpus");
    }

    #[test]
    fn json_is_well_formed_and_escaped() {
        let mut spec = named("steady", true).unwrap();
        spec.horizon = 30;
        spec.warmup = 5;
        let r = run_scenario(&spec, Algorithm::Vanilla, &ScenarioConfig::new(5)).unwrap();
        let json = to_json(&[r]);
        assert!(json.contains("\"scenarios\""));
        assert!(json.contains("\"scenario\": \"steady\""));
        assert!(json.contains("\"p99_tail_rel\""));
        assert!(json.trim_end().ends_with('}'));
        assert_eq!(json.matches("},").count(), 0, "single record needs no comma");
    }
}
