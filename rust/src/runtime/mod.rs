//! Runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO text) and
//! executes them through the PJRT CPU client from the coordinator's
//! decision loop — plus a native Rust scorer with identical semantics used
//! as fallback and cross-check.  See DESIGN.md (three-layer architecture).

// Not yet swept for full rustdoc coverage -- the crate-level
// `#![warn(missing_docs)]` allow-list (see ARCHITECTURE.md
// §Documentation).
#![allow(missing_docs)]

pub mod native;
pub mod pjrt;
pub mod problem;
pub mod shapes;

pub use pjrt::Engine;
pub use problem::{CandidateBatch, ScoreOut, ScoreProblem, VmEntry, Weights};
pub use shapes::Meta;

/// Scorer backend: PJRT artifacts when available, native math otherwise.
#[derive(Clone)]
pub enum Scorer {
    Pjrt(std::rc::Rc<Engine>),
    Native,
}

thread_local! {
    /// Engine loading costs ~1 s (PJRT client + XLA compilation of three
    /// artifacts).  Experiments run many clusters per process, so the
    /// compiled engine is cached per thread (PJRT handles are not Sync).
    static ENGINE_CACHE: std::cell::OnceCell<Option<std::rc::Rc<Engine>>> =
        const { std::cell::OnceCell::new() };
}

impl Scorer {
    /// Prefer PJRT; fall back to native when artifacts are missing.  The
    /// compiled engine is shared across all `auto()` calls on this thread.
    pub fn auto() -> Scorer {
        ENGINE_CACHE.with(|cell| {
            match cell.get_or_init(|| Engine::load_default().map(std::rc::Rc::new)) {
                Some(e) => Scorer::Pjrt(std::rc::Rc::clone(e)),
                None => Scorer::Native,
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scorer::Pjrt(_) => "pjrt",
            Scorer::Native => "native",
        }
    }

    /// Score a candidate batch.  Native scoring fans large batches out
    /// over the scorer thread pool (bit-identical to the serial loop).
    pub fn score(
        &self,
        problem: &ScoreProblem,
        batch: &CandidateBatch,
    ) -> anyhow::Result<Vec<ScoreOut>> {
        match self {
            Scorer::Pjrt(engine) => engine.score(problem, batch),
            Scorer::Native => Ok(native::score_batch_parallel(problem, batch)),
        }
    }

    /// Index of the lowest-total candidate, if any.
    pub fn argmin(
        &self,
        problem: &ScoreProblem,
        batch: &CandidateBatch,
    ) -> anyhow::Result<Option<(usize, ScoreOut)>> {
        let scores = self.score(problem, batch)?;
        Ok(scores
            .into_iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.total.partial_cmp(&b.total).unwrap())
            .map(|(i, s)| (i, s)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;
    use crate::workload::App;

    #[test]
    fn native_scorer_argmin() {
        let topo = Topology::paper();
        let n = topo.num_nodes();
        let mut mem = vec![0.0; n];
        mem[0] = 1.0;
        let prob = ScoreProblem::build(
            &topo,
            &[VmEntry { profile: App::Derby.profile(), vcpus: 4, mem_fractions: mem }],
            Weights::default(),
            Meta::expected(),
        )
        .unwrap();
        let scorer = Scorer::Native;
        let mut b = CandidateBatch::zeroed(prob.meta, 8);
        for node in [24usize, 0, 6] {
            let mut p = vec![vec![0.0; 36]; 1];
            p[0][node] = 1.0;
            b.push(&p);
        }
        let (idx, _) = scorer.argmin(&prob, &b).unwrap().unwrap();
        assert_eq!(idx, 1, "local candidate must win");
    }

    #[test]
    fn empty_batch_argmin_is_none() {
        let topo = Topology::tiny();
        let prob =
            ScoreProblem::build(&topo, &[], Weights::default(), Meta::expected()).unwrap();
        let b = CandidateBatch::zeroed(prob.meta, 8);
        assert!(Scorer::Native.argmin(&prob, &b).unwrap().is_none());
    }
}
