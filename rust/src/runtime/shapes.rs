//! AOT shape contract shared with the Python compile path.
//!
//! `python/compile/shapes.py` writes `artifacts/meta.txt`; this module
//! parses it and the runtime asserts the values before feeding buffers to
//! the compiled executables — a shape mismatch must fail loudly at load
//! time, not corrupt scores at run time.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// Fixed shapes of the compiled artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Meta {
    /// Candidate batch of the big scorer.
    pub batch: usize,
    /// Candidate batch of the low-latency scorer.
    pub batch_small: usize,
    /// Max VMs per scoring problem (rows are padded up to this).
    pub max_vms: usize,
    /// NUMA nodes the artifacts were compiled for.
    pub num_nodes: usize,
    /// Gradient steps inside the optimizer artifact.
    pub opt_steps: usize,
    /// Pallas kernel block size (informational).
    pub block_b: usize,
}

impl Meta {
    /// The values `python/compile/shapes.py` currently pins (kept in sync
    /// by `meta.txt` verification at load time and the cross-layer test).
    pub fn expected() -> Self {
        Self { batch: 64, batch_small: 8, max_vms: 32, num_nodes: 36, opt_steps: 60, block_b: 8 }
    }

    /// Parse the `key=value` lines of `meta.txt`.
    pub fn parse(text: &str) -> Result<Self> {
        let mut kv = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("bad meta line: {line:?}"))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<usize> {
            kv.get(k)
                .ok_or_else(|| anyhow!("meta.txt missing key {k}"))?
                .parse::<usize>()
                .with_context(|| format!("meta key {k}"))
        };
        let dtype = kv.get("dtype").map(String::as_str).unwrap_or("float32");
        if dtype != "float32" {
            bail!("unsupported artifact dtype {dtype}");
        }
        Ok(Self {
            batch: get("batch")?,
            batch_small: get("batch_small")?,
            max_vms: get("max_vms")?,
            num_nodes: get("num_nodes")?,
            opt_steps: get("opt_steps")?,
            block_b: get("block_b")?,
        })
    }

    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "batch=64\nbatch_small=8\nmax_vms=32\nnum_nodes=36\nopt_steps=60\nblock_b=8\ndtype=float32\n";

    #[test]
    fn parses_meta() {
        let m = Meta::parse(SAMPLE).unwrap();
        assert_eq!(m, Meta::expected());
    }

    #[test]
    fn missing_key_is_error() {
        assert!(Meta::parse("batch=64\n").is_err());
    }

    #[test]
    fn wrong_dtype_rejected() {
        let text = SAMPLE.replace("float32", "bfloat16");
        assert!(Meta::parse(&text).is_err());
    }

    #[test]
    fn malformed_line_rejected() {
        assert!(Meta::parse("nonsense").is_err());
    }

    #[test]
    fn artifact_meta_matches_expected_if_built() {
        // Cross-layer contract: if `make artifacts` has run, its meta must
        // agree with what this runtime was written against.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/meta.txt");
        if let Ok(m) = Meta::from_file(path) {
            assert_eq!(m, Meta::expected(), "artifacts/meta.txt drifted — re-run make artifacts");
        }
    }
}
