//! Pure-Rust reference scorer — the same cost model as the Pallas kernel
//! (`python/compile/kernels/ref.py`), used (a) as a fallback when the
//! artifacts have not been built, (b) to cross-validate the PJRT path in
//! tests, and (c) as the baseline in the hot-path benchmarks.
//!
//! Large batches fan out over a dedicated thread pool
//! ([`score_batch_parallel`]); candidates are scored independently, so
//! chunked evaluation is bit-identical to the serial loop.

use std::sync::{Arc, OnceLock};

use crate::util::pool::ThreadPool;

use super::problem::{CandidateBatch, ScoreOut, ScoreProblem};

/// Below this many candidates the fan-out overhead beats the win.
const PARALLEL_MIN_BATCH: usize = 16;

/// The scorer's own pool — deliberately distinct from
/// [`crate::util::pool::global`]: batch scoring runs *inside* experiment
/// jobs that occupy the global workers, and nesting one pool inside
/// itself deadlocks.
fn score_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        ThreadPool::new(n.min(8))
    })
}

/// Score a batch, fanning contiguous candidate chunks out over the scorer
/// pool when the batch is large.  Identical results to [`score_batch`] —
/// candidates never interact.
pub fn score_batch_parallel(problem: &ScoreProblem, batch: &CandidateBatch) -> Vec<ScoreOut> {
    let workers = score_pool().workers();
    if batch.len < PARALLEL_MIN_BATCH || workers < 2 {
        return score_batch(problem, batch);
    }
    let stride = batch.meta.max_vms * batch.meta.num_nodes;
    let chunk = batch.len.div_ceil(workers);
    let problem = Arc::new(problem.clone());
    let jobs: Vec<(Arc<ScoreProblem>, CandidateBatch)> = (0..batch.len)
        .step_by(chunk)
        .map(|lo| {
            let hi = (lo + chunk).min(batch.len);
            let sub = CandidateBatch {
                meta: batch.meta,
                p: batch.p[lo * stride..hi * stride].to_vec(),
                len: hi - lo,
                batch: hi - lo,
            };
            (Arc::clone(&problem), sub)
        })
        .collect();
    score_pool()
        .scope_map(jobs, |(prob, sub)| score_batch(prob.as_ref(), &sub))
        .into_iter()
        .flatten()
        .collect()
}

/// Score a single whole-system placement (a one-candidate batch) — the
/// reference the delta-scoring oracle tests compare against, and the
/// cheapest way to get a baseline score for one configuration.
pub fn score_one(problem: &ScoreProblem, placement: &[Vec<f64>]) -> ScoreOut {
    let mut b = CandidateBatch::zeroed(problem.meta, 1);
    b.push(placement);
    score_batch(problem, &b)[0]
}

/// Score every live candidate in the batch.
pub fn score_batch(problem: &ScoreProblem, batch: &CandidateBatch) -> Vec<ScoreOut> {
    let v = problem.meta.max_vms;
    let n = problem.meta.num_nodes;
    let mut out = Vec::with_capacity(batch.len);
    let mut pd = vec![0.0f32; n]; // one row of P @ D at a time
    for b in 0..batch.len {
        let p = &batch.p[b * v * n..(b + 1) * v * n];
        let mut locality = 0.0f32;
        let mut contention = 0.0f32;
        // locality: sum_v s_v * sum_j (P@D)[v,j] * M[v,j]
        for i in 0..v {
            let prow = &p[i * n..(i + 1) * n];
            if problem.cores[i] == 0.0 && prow.iter().all(|&x| x == 0.0) {
                continue;
            }
            pd.iter_mut().for_each(|x| *x = 0.0);
            for (k, &pik) in prow.iter().enumerate() {
                if pik == 0.0 {
                    continue;
                }
                let drow = &problem.d[k * n..(k + 1) * n];
                for j in 0..n {
                    pd[j] += pik * drow[j];
                }
            }
            let mrow = &problem.m[i * n..(i + 1) * n];
            let mut loc_i = 0.0f32;
            for j in 0..n {
                loc_i += pd[j] * mrow[j];
            }
            locality += problem.s[i] * loc_i;

            // contention: sum_w C[v,w] * <P_v, P_w>
            for w_idx in 0..v {
                if w_idx == i {
                    continue;
                }
                let cvw = problem.c[i * v + w_idx];
                if cvw == 0.0 {
                    continue;
                }
                let prow_w = &p[w_idx * n..(w_idx + 1) * n];
                let mut overlap = 0.0f32;
                for j in 0..n {
                    overlap += prow[j] * prow_w[j];
                }
                contention += cvw * overlap;
            }
        }
        // overload + bandwidth overload: sum_j relu(demand_j - cap_j)^2
        let mut overload = 0.0f32;
        let mut bw_over = 0.0f32;
        for j in 0..n {
            let mut load = 0.0f32;
            let mut bw_load = 0.0f32;
            for i in 0..v {
                load += problem.cores[i] * p[i * n + j];
                bw_load += problem.bw[i] * p[i * n + j];
            }
            let over = (load - problem.cap[j]).max(0.0);
            overload += over * over;
            let bwo = (bw_load - problem.bwcap[j]).max(0.0);
            bw_over += bwo * bwo;
        }
        let total = problem.w[0] * locality
            + problem.w[1] * contention
            + problem.w[2] * overload
            + problem.w[3] * bw_over;
        out.push(ScoreOut { total, locality, contention, overload, bw_over });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::problem::{VmEntry, Weights};
    use crate::runtime::shapes::Meta;
    use crate::topology::Topology;
    use crate::util::rng::Rng;
    use crate::util::testkit::{prop_assert, propcheck};
    use crate::workload::App;

    fn problem_with(apps: &[(App, usize, usize)]) -> (ScoreProblem, Topology) {
        let topo = Topology::paper();
        let n = topo.num_nodes();
        let entries: Vec<VmEntry> = apps
            .iter()
            .map(|(app, vcpus, node)| {
                let mut mem = vec![0.0; n];
                mem[*node] = 1.0;
                VmEntry { profile: app.profile(), vcpus: *vcpus, mem_fractions: mem }
            })
            .collect();
        (ScoreProblem::build(&topo, &entries, Weights::default(), Meta::expected()).unwrap(), topo)
    }

    fn one_hot(v: usize, n: usize, assignments: &[(usize, usize)]) -> Vec<Vec<f64>> {
        let mut p = vec![vec![0.0; n]; v];
        for (vm, node) in assignments {
            p[*vm][*node] = 1.0;
        }
        p
    }

    #[test]
    fn local_beats_remote() {
        let (prob, _) = problem_with(&[(App::Neo4j, 4, 0)]);
        let mut b = CandidateBatch::zeroed(prob.meta, 8);
        b.push(&one_hot(2, 36, &[(0, 0)])); // local to memory
        b.push(&one_hot(2, 36, &[(0, 24)])); // 2 hops away
        let scores = score_batch(&prob, &b);
        assert!(scores[0].total < scores[1].total);
        assert!(scores[0].locality < scores[1].locality);
    }

    #[test]
    fn separating_rabbit_from_devil_wins() {
        let (prob, _) = problem_with(&[(App::Mpegaudio, 4, 0), (App::Fft, 4, 0)]);
        let mut b = CandidateBatch::zeroed(prob.meta, 8);
        b.push(&one_hot(2, 36, &[(0, 0), (1, 0)])); // shared node
        b.push(&one_hot(2, 36, &[(0, 0), (1, 2)])); // separated (same server)
        let scores = score_batch(&prob, &b);
        assert!(scores[1].total < scores[0].total, "{scores:?}");
        assert!(scores[1].contention < scores[0].contention);
    }

    #[test]
    fn overload_penalized() {
        let (prob, topo) = problem_with(&[(App::Derby, 16, 0)]);
        let mut b = CandidateBatch::zeroed(prob.meta, 8);
        // 16 vcpus on one 4-core node: overload 12^2
        b.push(&one_hot(2, 36, &[(0, 0)]));
        // spread over 4 nodes of server 0: no overload
        let mut spread = vec![vec![0.0; 36]; 2];
        for node in 0..4 {
            spread[0][node] = 0.25;
        }
        b.push(&spread);
        let scores = score_batch(&prob, &b);
        assert!(scores[0].overload > 0.0);
        assert_eq!(scores[1].overload, 0.0);
        assert!(scores[1].total < scores[0].total);
        let _ = topo;
    }

    #[test]
    fn empty_batch_gives_empty_scores() {
        let (prob, _) = problem_with(&[(App::Sor, 4, 0)]);
        let b = CandidateBatch::zeroed(prob.meta, 8);
        assert!(score_batch(&prob, &b).is_empty());
        assert!(score_batch_parallel(&prob, &b).is_empty());
    }

    #[test]
    fn parallel_scoring_is_bit_identical_to_serial() {
        let (prob, _) = problem_with(&[(App::Stream, 4, 0), (App::Neo4j, 8, 5)]);
        let mut rng = Rng::new(3);
        let mut b = CandidateBatch::zeroed(prob.meta, 64);
        for _ in 0..40 {
            let mut p = vec![vec![0.0; 36]; 2];
            for row in p.iter_mut() {
                for f in rng.simplex(3) {
                    row[rng.below(36)] += f;
                }
                let sum: f64 = row.iter().sum();
                row.iter_mut().for_each(|x| *x /= sum);
            }
            b.push(&p);
        }
        let serial = score_batch(&prob, &b);
        let par = score_batch_parallel(&prob, &b);
        assert_eq!(serial.len(), par.len());
        for (a, c) in serial.iter().zip(par.iter()) {
            assert_eq!(a, c, "chunked scoring must be bit-identical");
        }
    }

    #[test]
    fn total_is_weighted_sum_property() {
        propcheck("total = w·components", 50, |rng: &mut Rng| {
            let (prob, _) = problem_with(&[(App::Stream, 4, 0), (App::Sunflow, 8, 5)]);
            let mut b = CandidateBatch::zeroed(prob.meta, 8);
            for _ in 0..4 {
                let mut p = vec![vec![0.0; 36]; 2];
                for row in p.iter_mut() {
                    // random sparse distribution over a few nodes
                    for f in rng.simplex(4) {
                        row[rng.below(36)] += f;
                    }
                    let sum: f64 = row.iter().sum();
                    row.iter_mut().for_each(|x| *x /= sum);
                }
                b.push(&p);
            }
            let scores = score_batch(&prob, &b);
            for sc in scores {
                let want = prob.w[0] * sc.locality
                    + prob.w[1] * sc.contention
                    + prob.w[2] * sc.overload
                    + prob.w[3] * sc.bw_over;
                if (want - sc.total).abs() > 1e-3 * (1.0 + want.abs()) {
                    return Err(format!("total {} != {}", sc.total, want));
                }
            }
            prop_assert(true, "")
        });
    }
}
