//! The scoring problem: the dense matrices the L1/L2 scorer consumes,
//! built from the live system state (topology + VMs) and padded to the
//! artifact shapes.

use anyhow::{bail, Result};

use super::shapes::Meta;
use crate::topology::Topology;
use crate::workload::{pair_penalty, AnimalClass, AppProfile};

/// Cost-model weights `(w_loc, w_cont, w_over, w_bw)` — see `ref.py`.
#[derive(Debug, Clone, Copy)]
pub struct Weights {
    pub locality: f32,
    pub contention: f32,
    pub overload: f32,
    /// Per-node memory-bandwidth overload (GB/s)² coefficient.
    pub bandwidth: f32,
}

impl Default for Weights {
    fn default() -> Self {
        // Calibrated so one fully-remote sensitive VM, one bad class pair,
        // one overbooked core, and ~10 GB/s of controller oversubscription
        // are comparable offences.
        Self { locality: 1.0, contention: 20.0, overload: 400.0, bandwidth: 2.0 }
    }
}

/// Dense, padded scorer inputs.  Row `i < vms` corresponds to
/// `vm_order[i]`; rows `>= vms` are zero padding.
#[derive(Debug, Clone)]
pub struct ScoreProblem {
    pub meta: Meta,
    /// Live VM count (≤ meta.max_vms).
    pub vms: usize,
    /// `[N, N]` distance matrix, row-major.
    pub d: Vec<f32>,
    /// `[V, N]` memory fractions.
    pub m: Vec<f32>,
    /// `[V, V]` class-pair penalties (zero diagonal / padding).
    pub c: Vec<f32>,
    /// `[V]` remote sensitivity.
    pub s: Vec<f32>,
    /// `[V]` vCPU counts.
    pub cores: Vec<f32>,
    /// `[N]` core capacity per node.
    pub cap: Vec<f32>,
    /// `[4]` weights.
    pub w: Vec<f32>,
    /// `[V]` total memory-bandwidth demand per VM, GB/s.
    pub bw: Vec<f32>,
    /// `[N]` memory controller bandwidth per node, GB/s.
    pub bwcap: Vec<f32>,
}

/// Per-VM inputs for problem construction.
#[derive(Debug, Clone)]
pub struct VmEntry {
    pub profile: AppProfile,
    pub vcpus: usize,
    /// Memory fractions per node (length = topo nodes).
    pub mem_fractions: Vec<f64>,
}

impl ScoreProblem {
    /// Build from live state.  Fails if the system exceeds artifact bounds.
    pub fn build(
        topo: &Topology,
        entries: &[VmEntry],
        weights: Weights,
        meta: Meta,
    ) -> Result<Self> {
        let n_live = topo.num_nodes();
        if n_live > meta.num_nodes {
            bail!("topology has {n_live} nodes, artifacts compiled for {}", meta.num_nodes);
        }
        if entries.len() > meta.max_vms {
            bail!("{} VMs exceed artifact capacity {}", entries.len(), meta.max_vms);
        }
        let (v, n) = (meta.max_vms, meta.num_nodes);

        let mut d = vec![0.0f32; n * n];
        for i in 0..n_live {
            for j in 0..n_live {
                d[i * n + j] = topo
                    .distance(crate::topology::NodeId(i), crate::topology::NodeId(j))
                    as f32;
            }
        }
        // Padding nodes are unreachable: huge distance + zero capacity, so
        // any mass placed there is dominated.
        for i in 0..n {
            for j in 0..n {
                if i >= n_live || j >= n_live {
                    d[i * n + j] = 1e4;
                }
            }
        }

        let mut m = vec![0.0f32; v * n];
        let mut c = vec![0.0f32; v * v];
        let mut s = vec![0.0f32; v];
        let mut cores = vec![0.0f32; v];
        let mut bw = vec![0.0f32; v];
        for (i, e) in entries.iter().enumerate() {
            bw[i] = (e.profile.bw_gbs_per_vcpu * e.vcpus as f64) as f32;
            for (j, f) in e.mem_fractions.iter().enumerate().take(n_live) {
                m[i * n + j] = *f as f32;
            }
            s[i] = if e.profile.sensitivity.is_sensitive() { 1.0 } else { 0.3 };
            // Weight locality by how memory-bound the app actually is.
            s[i] *= (e.profile.mem_stall_frac as f32).max(0.05);
            cores[i] = e.vcpus as f32;
            for (j, o) in entries.iter().enumerate() {
                if i != j {
                    c[i * v + j] = pair_penalty(e.profile.class, o.profile.class) as f32;
                }
            }
        }

        // Capacity = schedulable hw threads per node (the paper counts its
        // 288 "cores" this way; one vCPU per hw thread = no overbooking).
        let slots = (topo.spec.cores_per_node * topo.spec.threads_per_core) as f32;
        let mut cap = vec![0.0f32; n];
        for c in cap.iter_mut().take(n_live) {
            *c = slots;
        }

        let mut bwcap = vec![0.0f32; n];
        for b in bwcap.iter_mut().take(n_live) {
            *b = topo.spec.mem_bw_per_node_gbs as f32;
        }

        Ok(Self {
            meta,
            vms: entries.len(),
            d,
            m,
            c,
            s,
            cores,
            cap,
            w: vec![weights.locality, weights.contention, weights.overload,
                    weights.bandwidth],
            bw,
            bwcap,
        })
    }

    /// Free capacity variant: subtract cores already pinned by VMs *not*
    /// part of this problem (so candidates cannot overload foreign cores).
    pub fn with_reduced_capacity(mut self, used_per_node: &[f64]) -> Self {
        for (j, used) in used_per_node.iter().enumerate().take(self.cap.len()) {
            self.cap[j] = (self.cap[j] - *used as f32).max(0.0);
        }
        self
    }

    // ---- in-place patching (the coordinator's persistent DeltaProblem) --

    /// Overwrite row `i`'s per-VM inputs in place: memory fractions,
    /// sensitivity, cores, bandwidth, and the class-pair row *and* column
    /// against `classes` (the animal class of every live row, `classes[i]`
    /// included).  Writes exactly the values [`Self::build`] would write
    /// for the same entry — bit-identical, so a patched problem equals a
    /// fresh rebuild (property-tested in `tests/properties.rs`).
    pub fn set_entry(&mut self, i: usize, e: &VmEntry, classes: &[AnimalClass]) {
        let (v, n) = (self.meta.max_vms, self.meta.num_nodes);
        assert!(i < v, "row {i} out of range ({v} max)");
        assert!(classes.len() <= v, "class list exceeds problem rows");
        self.bw[i] = (e.profile.bw_gbs_per_vcpu * e.vcpus as f64) as f32;
        let mrow = &mut self.m[i * n..(i + 1) * n];
        mrow.iter_mut().for_each(|x| *x = 0.0);
        for (j, f) in e.mem_fractions.iter().enumerate().take(n) {
            mrow[j] = *f as f32;
        }
        self.s[i] = if e.profile.sensitivity.is_sensitive() { 1.0 } else { 0.3 };
        self.s[i] *= (e.profile.mem_stall_frac as f32).max(0.05);
        self.cores[i] = e.vcpus as f32;
        for (j, cj) in classes.iter().enumerate() {
            if j == i {
                self.c[i * v + i] = 0.0;
            } else {
                self.c[i * v + j] = pair_penalty(e.profile.class, *cj) as f32;
                self.c[j * v + i] = pair_penalty(*cj, e.profile.class) as f32;
            }
        }
    }

    /// Zero row `i` back to padding state (per-VM inputs plus its class
    /// row and column) — the removal half of the patch protocol.
    pub fn clear_entry(&mut self, i: usize) {
        let (v, n) = (self.meta.max_vms, self.meta.num_nodes);
        assert!(i < v, "row {i} out of range ({v} max)");
        self.m[i * n..(i + 1) * n].iter_mut().for_each(|x| *x = 0.0);
        self.s[i] = 0.0;
        self.cores[i] = 0.0;
        self.bw[i] = 0.0;
        for j in 0..v {
            self.c[i * v + j] = 0.0;
            self.c[j * v + i] = 0.0;
        }
    }

    /// Set the live VM count after patching rows.
    pub fn set_vm_count(&mut self, vms: usize) {
        assert!(vms <= self.meta.max_vms, "{vms} VMs exceed {}", self.meta.max_vms);
        self.vms = vms;
    }
}

/// A candidate batch: `B` placements, each `[V, N]` row-major fractions.
#[derive(Debug, Clone)]
pub struct CandidateBatch {
    pub meta: Meta,
    /// `[B, V, N]` flattened.
    pub p: Vec<f32>,
    /// Number of real candidates (rest is padding).
    pub len: usize,
    pub batch: usize,
}

impl CandidateBatch {
    /// Allocate a zeroed batch of capacity `batch` (must be one of the
    /// compiled batch sizes).
    pub fn zeroed(meta: Meta, batch: usize) -> Self {
        Self { meta, p: vec![0.0; batch * meta.max_vms * meta.num_nodes], len: 0, batch }
    }

    /// Append a candidate given per-VM node fractions.  Rows beyond the
    /// problem's VM count stay zero.
    pub fn push(&mut self, placement: &[Vec<f64>]) {
        assert!(self.len < self.batch, "batch full");
        let (v, n) = (self.meta.max_vms, self.meta.num_nodes);
        let base = self.len * v * n;
        for (i, row) in placement.iter().enumerate().take(v) {
            for (j, f) in row.iter().enumerate().take(n) {
                self.p[base + i * n + j] = *f as f32;
            }
        }
        self.len += 1;
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append a candidate equal to `placement` with row `row` replaced by
    /// `replacement` — the mapper's one-row-varies case, without cloning
    /// the whole placement matrix per candidate.
    pub fn push_with_row(&mut self, placement: &[Vec<f64>], row: usize, replacement: &[f64]) {
        assert!(self.len < self.batch, "batch full");
        let (v, n) = (self.meta.max_vms, self.meta.num_nodes);
        let base = self.len * v * n;
        for (i, r) in placement.iter().enumerate().take(v) {
            let src: &[f64] = if i == row { replacement } else { r.as_slice() };
            for (j, f) in src.iter().enumerate().take(n) {
                self.p[base + i * n + j] = *f as f32;
            }
        }
        self.len += 1;
    }
}

/// Scorer output per candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScoreOut {
    pub total: f32,
    pub locality: f32,
    pub contention: f32,
    pub overload: f32,
    pub bw_over: f32,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;
    use crate::workload::App;

    fn entry(app: App, vcpus: usize, node: usize, n: usize) -> VmEntry {
        let mut mem = vec![0.0; n];
        mem[node] = 1.0;
        VmEntry { profile: app.profile(), vcpus, mem_fractions: mem }
    }

    #[test]
    fn build_pads_to_meta_shapes() {
        let topo = Topology::paper();
        let meta = Meta::expected();
        let entries =
            vec![entry(App::Neo4j, 8, 0, 36), entry(App::Stream, 4, 1, 36)];
        let p = ScoreProblem::build(&topo, &entries, Weights::default(), meta).unwrap();
        assert_eq!(p.d.len(), 36 * 36);
        assert_eq!(p.m.len(), 32 * 36);
        assert_eq!(p.c.len(), 32 * 32);
        assert_eq!(p.vms, 2);
        // class penalty Neo4j(Sheep) vs Stream(Devil): victim sheep = 1.0
        assert_eq!(p.c[0 * 32 + 1], 1.0);
        assert_eq!(p.c[1 * 32 + 0], 0.3);
        // diagonal zero
        assert_eq!(p.c[0], 0.0);
    }

    #[test]
    fn too_many_vms_rejected() {
        let topo = Topology::paper();
        let meta = Meta::expected();
        let entries: Vec<VmEntry> =
            (0..33).map(|_| entry(App::Sockshop, 1, 0, 36)).collect();
        assert!(ScoreProblem::build(&topo, &entries, Weights::default(), meta).is_err());
    }

    #[test]
    fn tiny_topology_pads_nodes() {
        let topo = Topology::tiny(); // 4 nodes
        let meta = Meta::expected();
        let p = ScoreProblem::build(&topo, &[entry(App::Fft, 2, 0, 4)], Weights::default(), meta)
            .unwrap();
        // real node distance kept, padding distance huge, padding cap zero
        assert_eq!(p.d[0], 10.0);
        assert_eq!(p.d[5 * 36 + 5], 1e4);
        let slots = (topo.spec.cores_per_node * topo.spec.threads_per_core) as f32;
        assert_eq!(p.cap[3], slots);
        assert_eq!(p.cap[4], 0.0);
    }

    #[test]
    fn candidate_batch_layout() {
        let meta = Meta::expected();
        let mut b = CandidateBatch::zeroed(meta, 8);
        let mut place = vec![vec![0.0; 36]; 2];
        place[0][3] = 1.0;
        place[1][0] = 0.5;
        place[1][1] = 0.5;
        b.push(&place);
        assert_eq!(b.len, 1);
        assert_eq!(b.p[0 * 36 + 3], 1.0);
        assert_eq!(b.p[1 * 36 + 0], 0.5);
        // second candidate region untouched
        assert!(b.p[32 * 36..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn set_entry_patches_to_exactly_a_fresh_build() {
        let topo = Topology::paper();
        let meta = Meta::expected();
        let e1 = entry(App::Neo4j, 8, 0, 36);
        let e2 = entry(App::Stream, 4, 1, 36);
        let e3 = entry(App::Fft, 2, 5, 36);
        let want =
            ScoreProblem::build(&topo, &[e1.clone(), e3.clone()], Weights::default(), meta)
                .unwrap();
        // Start from a different population and patch row 1 into place.
        let mut got =
            ScoreProblem::build(&topo, &[e1.clone(), e2], Weights::default(), meta).unwrap();
        let classes = [e1.profile.class, e3.profile.class];
        got.set_entry(1, &e3, &classes);
        got.set_vm_count(2);
        assert_eq!(got.m, want.m);
        assert_eq!(got.c, want.c);
        assert_eq!(got.s, want.s);
        assert_eq!(got.cores, want.cores);
        assert_eq!(got.bw, want.bw);
        assert_eq!(got.vms, want.vms);
    }

    #[test]
    fn clear_entry_restores_padding_state() {
        let topo = Topology::paper();
        let meta = Meta::expected();
        let e1 = entry(App::Neo4j, 8, 0, 36);
        let e2 = entry(App::Stream, 4, 1, 36);
        let want = ScoreProblem::build(&topo, &[e1.clone()], Weights::default(), meta).unwrap();
        let mut got =
            ScoreProblem::build(&topo, &[e1, e2], Weights::default(), meta).unwrap();
        got.clear_entry(1);
        got.set_vm_count(1);
        assert_eq!(got.m, want.m);
        assert_eq!(got.c, want.c);
        assert_eq!(got.s, want.s);
        assert_eq!(got.cores, want.cores);
        assert_eq!(got.bw, want.bw);
    }

    #[test]
    fn push_with_row_equals_push_of_mutated_rows() {
        let meta = Meta::expected();
        let mut rows = vec![vec![0.0; 36]; 3];
        rows[0][3] = 1.0;
        rows[1][0] = 0.5;
        rows[1][1] = 0.5;
        rows[2][7] = 1.0;
        let mut replacement = vec![0.0; 36];
        replacement[12] = 0.25;
        replacement[13] = 0.75;

        let mut a = CandidateBatch::zeroed(meta, 8);
        a.push_with_row(&rows, 1, &replacement);
        let mut mutated = rows.clone();
        mutated[1] = replacement;
        let mut b = CandidateBatch::zeroed(meta, 8);
        b.push(&mutated);
        assert_eq!(a.p, b.p);
        assert_eq!(a.len, b.len);
    }

    #[test]
    fn reduced_capacity_saturates_at_zero() {
        let topo = Topology::tiny();
        let meta = Meta::expected();
        let p = ScoreProblem::build(&topo, &[], Weights::default(), meta).unwrap();
        let p = p.with_reduced_capacity(&[1.0, 99.0]);
        let slots = (topo.spec.cores_per_node * topo.spec.threads_per_core) as f32;
        assert_eq!(p.cap[0], slots - 1.0);
        assert_eq!(p.cap[1], 0.0);
    }
}
