//! PJRT execution of the AOT artifacts (the L2/L1 compute path).
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`,
//! compiles them once on the PJRT CPU client, and executes them from the
//! coordinator's decision loop.  Python never runs here.
//!
//! Wiring follows /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`, with `return_tuple=True` on the Python
//! side so every artifact returns one tuple literal.
//!
//! The `xla` crate is not part of the offline registry, so the whole PJRT
//! path is gated behind the `pjrt` cargo feature.  Without it a stub
//! [`Engine`] whose `load` always fails is compiled instead —
//! `Scorer::auto()` then falls back to the native Rust scorer, which
//! implements identical semantics (cross-checked by
//! `pjrt_matches_native_scorer` when the feature is on).

#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};

#[cfg(feature = "pjrt")]
use anyhow::{anyhow, bail, Context, Result};

#[cfg(feature = "pjrt")]
use super::problem::{CandidateBatch, ScoreOut, ScoreProblem};
#[cfg(feature = "pjrt")]
use super::shapes::Meta;

/// Compiled artifacts + the PJRT client that owns them.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    scorer: xla::PjRtLoadedExecutable,
    scorer_small: xla::PjRtLoadedExecutable,
    optimizer: xla::PjRtLoadedExecutable,
    pub meta: Meta,
    /// Cumulative number of scorer invocations (telemetry).
    pub scorer_calls: std::cell::Cell<u64>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Load from an artifacts directory (`make artifacts` output).
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Engine> {
        let dir = dir.as_ref();
        let meta = Meta::from_file(dir.join("meta.txt"))
            .with_context(|| format!("loading meta from {}", dir.display()))?;
        if meta != Meta::expected() {
            bail!("artifact meta {:?} != runtime contract {:?}", meta, Meta::expected());
        }
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path: PathBuf = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(wrap)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(wrap).with_context(|| format!("compiling {name}"))
        };
        Ok(Engine {
            scorer: compile("scorer.hlo.txt")?,
            scorer_small: compile("scorer_small.hlo.txt")?,
            optimizer: compile("optimizer.hlo.txt")?,
            client,
            meta,
            scorer_calls: std::cell::Cell::new(0),
        })
    }

    /// Load from the conventional location (`$DVRM_ARTIFACTS` or
    /// `<manifest>/artifacts`), or fall back to `None` when absent —
    /// callers then use the native scorer.
    pub fn load_default() -> Option<Engine> {
        let dir = std::env::var("DVRM_ARTIFACTS")
            .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string());
        match Engine::load(&dir) {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("PJRT engine unavailable ({err:#}); using native scorer");
                None
            }
        }
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        // One copy straight into the literal (vec1 + reshape would copy and
        // re-allocate; this path shows up on the decision-loop profile).
        let dims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
        };
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &dims, bytes)
            .map_err(wrap)
    }

    /// Score a candidate batch (padded to whichever compiled batch size
    /// fits).  Returns one [`ScoreOut`] per live candidate.
    pub fn score(&self, problem: &ScoreProblem, batch: &CandidateBatch) -> Result<Vec<ScoreOut>> {
        if batch.is_empty() {
            return Ok(Vec::new());
        }
        let (v, n) = (self.meta.max_vms as i64, self.meta.num_nodes as i64);
        let (exe, bsz) = if batch.len <= self.meta.batch_small {
            (&self.scorer_small, self.meta.batch_small)
        } else if batch.len <= self.meta.batch {
            (&self.scorer, self.meta.batch)
        } else {
            bail!("candidate batch {} exceeds compiled max {}", batch.len, self.meta.batch);
        };
        // Pad the flat placement buffer to bsz candidates — zero-copy when
        // the batch was allocated at the compiled size (the common case).
        let cand_elems = (v * n) as usize;
        let mut padded;
        let p: &[f32] = if batch.batch == bsz && batch.p.len() == bsz * cand_elems {
            &batch.p
        } else {
            padded = vec![0.0f32; bsz * cand_elems];
            padded[..batch.len * cand_elems]
                .copy_from_slice(&batch.p[..batch.len * cand_elems]);
            &padded
        };

        let args = [
            Self::lit_f32(p, &[bsz as i64, v, n])?,
            Self::lit_f32(&problem.d, &[n, n])?,
            Self::lit_f32(&problem.m, &[v, n])?,
            Self::lit_f32(&problem.c, &[v, v])?,
            Self::lit_f32(&problem.s, &[v])?,
            Self::lit_f32(&problem.cores, &[v])?,
            Self::lit_f32(&problem.cap, &[n])?,
            Self::lit_f32(&problem.w, &[4])?,
            Self::lit_f32(&problem.bw, &[v])?,
            Self::lit_f32(&problem.bwcap, &[n])?,
        ];
        let result = exe.execute::<xla::Literal>(&args).map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        self.scorer_calls.set(self.scorer_calls.get() + 1);
        let mut parts = result.to_tuple().map_err(wrap)?;
        if parts.len() != 5 {
            bail!("scorer returned {}-tuple, want 5", parts.len());
        }
        let bw_over = parts.pop().unwrap().to_vec::<f32>().map_err(wrap)?;
        let over = parts.pop().unwrap().to_vec::<f32>().map_err(wrap)?;
        let cont = parts.pop().unwrap().to_vec::<f32>().map_err(wrap)?;
        let loc = parts.pop().unwrap().to_vec::<f32>().map_err(wrap)?;
        let total = parts.pop().unwrap().to_vec::<f32>().map_err(wrap)?;

        let vs = self.meta.max_vms;
        Ok((0..batch.len)
            .map(|b| ScoreOut {
                total: total[b],
                locality: loc[b * vs..(b + 1) * vs].iter().sum(),
                contention: cont[b * vs..(b + 1) * vs].iter().sum(),
                overload: over[b],
                bw_over: bw_over[b],
            })
            .collect())
    }

    /// Run the relaxed whole-system optimizer artifact.
    ///
    /// `logits0` is `[V, N]` (e.g. log of the current placement + noise);
    /// returns the optimized `[V, N]` placement fractions (rows of live
    /// VMs sum to 1) and the cost trace.
    pub fn optimize(
        &self,
        problem: &ScoreProblem,
        logits0: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let (v, n) = (self.meta.max_vms as i64, self.meta.num_nodes as i64);
        if logits0.len() != (v * n) as usize {
            bail!("logits0 len {} != {}", logits0.len(), v * n);
        }
        let mut live = vec![0.0f32; v as usize];
        for (i, l) in live.iter_mut().enumerate().take(problem.vms) {
            let _ = i;
            *l = 1.0;
        }
        let args = [
            Self::lit_f32(logits0, &[v, n])?,
            Self::lit_f32(&problem.d, &[n, n])?,
            Self::lit_f32(&problem.m, &[v, n])?,
            Self::lit_f32(&problem.c, &[v, v])?,
            Self::lit_f32(&problem.s, &[v])?,
            Self::lit_f32(&problem.cores, &[v])?,
            Self::lit_f32(&problem.cap, &[n])?,
            Self::lit_f32(&problem.w, &[4])?,
            Self::lit_f32(&problem.bw, &[v])?,
            Self::lit_f32(&problem.bwcap, &[n])?,
            Self::lit_f32(&live, &[v])?,
        ];
        let result = self.optimizer.execute::<xla::Literal>(&args).map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        let (p_opt, trace) = result.to_tuple2().map_err(wrap)?;
        Ok((p_opt.to_vec::<f32>().map_err(wrap)?, trace.to_vec::<f32>().map_err(wrap)?))
    }
}

#[cfg(feature = "pjrt")]
fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

// ---------------------------------------------------------------- stub ----

/// Stub engine compiled when the `pjrt` feature is off: loading always
/// fails, so `Scorer::auto()` falls back to the native scorer.  The type
/// and its surface exist so the mapper, benches and examples compile
/// unchanged.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    pub meta: super::shapes::Meta,
    /// Cumulative number of scorer invocations (telemetry).
    pub scorer_calls: std::cell::Cell<u64>,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Always fails: PJRT support is not compiled in.
    pub fn load<P: AsRef<std::path::Path>>(dir: P) -> anyhow::Result<Engine> {
        anyhow::bail!(
            "PJRT support not compiled in (enable the `pjrt` feature and vendor the \
             `xla` crate); artifacts at {} ignored",
            dir.as_ref().display()
        )
    }

    /// `None`: callers use the native scorer.
    pub fn load_default() -> Option<Engine> {
        None
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }

    pub fn score(
        &self,
        _problem: &super::problem::ScoreProblem,
        _batch: &super::problem::CandidateBatch,
    ) -> anyhow::Result<Vec<super::problem::ScoreOut>> {
        anyhow::bail!("PJRT support not compiled in")
    }

    pub fn optimize(
        &self,
        _problem: &super::problem::ScoreProblem,
        _logits0: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        anyhow::bail!("PJRT support not compiled in")
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::runtime::native;
    use crate::runtime::problem::{VmEntry, Weights};
    use crate::topology::Topology;
    use crate::util::rng::Rng;
    use crate::workload::App;

    fn engine() -> Engine {
        Engine::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
            .expect("run `make artifacts` before cargo test")
    }

    fn problem() -> ScoreProblem {
        let topo = Topology::paper();
        let n = topo.num_nodes();
        let entries: Vec<VmEntry> = [
            (App::Neo4j, 72usize, 0usize),
            (App::Stream, 8, 6),
            (App::Mpegaudio, 8, 12),
            (App::Fft, 16, 18),
        ]
        .iter()
        .map(|(app, vcpus, node)| {
            let mut mem = vec![0.0; n];
            mem[*node] = 1.0;
            VmEntry { profile: app.profile(), vcpus: *vcpus, mem_fractions: mem }
        })
        .collect();
        ScoreProblem::build(&topo, &entries, Weights::default(), Meta::expected()).unwrap()
    }

    fn random_batch(meta: Meta, len: usize, vms: usize, seed: u64) -> CandidateBatch {
        let bsz = if len <= meta.batch_small { meta.batch_small } else { meta.batch };
        let mut b = CandidateBatch::zeroed(meta, bsz);
        let mut rng = Rng::new(seed);
        for _ in 0..len {
            let mut p = vec![vec![0.0; meta.num_nodes]; vms];
            for row in p.iter_mut() {
                for f in rng.simplex(3) {
                    row[rng.below(36)] += f;
                }
                let s: f64 = row.iter().sum();
                row.iter_mut().for_each(|x| *x /= s);
            }
            b.push(&p);
        }
        b
    }

    #[test]
    fn pjrt_matches_native_scorer() {
        let eng = engine();
        let prob = problem();
        for (len, seed) in [(3usize, 1u64), (8, 2), (64, 3)] {
            let batch = random_batch(eng.meta, len, prob.vms, seed);
            let pjrt = eng.score(&prob, &batch).unwrap();
            let nat = native::score_batch(&prob, &batch);
            assert_eq!(pjrt.len(), nat.len());
            for (a, b) in pjrt.iter().zip(nat.iter()) {
                assert!(
                    (a.total - b.total).abs() <= 1e-2 * (1.0 + b.total.abs()),
                    "total pjrt={} native={}",
                    a.total,
                    b.total
                );
                assert!((a.overload - b.overload).abs() <= 1e-2 * (1.0 + b.overload.abs()));
            }
        }
    }

    #[test]
    fn scorer_prefers_local_placement() {
        let eng = engine();
        let prob = problem();
        let mut b = CandidateBatch::zeroed(eng.meta, eng.meta.batch_small);
        let mut local = vec![vec![0.0; 36]; prob.vms];
        let mut remote = local.clone();
        // VM 1 (stream, mem on node 6): local vs far server
        local[1][6] = 1.0;
        remote[1][30] = 1.0;
        for vm in [0usize, 2, 3] {
            let node = [0usize, 0, 12, 18][vm];
            local[vm][node] = 1.0;
            remote[vm][node] = 1.0;
        }
        b.push(&local);
        b.push(&remote);
        let scores = eng.score(&prob, &b).unwrap();
        assert!(scores[0].total < scores[1].total);
    }

    #[test]
    fn oversize_batch_rejected() {
        let eng = engine();
        let prob = problem();
        let mut b = CandidateBatch::zeroed(eng.meta, eng.meta.batch);
        b.batch = eng.meta.batch + 1; // simulate overflow
        b.len = eng.meta.batch + 1;
        b.p = vec![0.0; (eng.meta.batch + 1) * 32 * 36];
        assert!(eng.score(&prob, &b).is_err());
    }

    #[test]
    fn optimizer_reduces_cost_and_localizes() {
        let eng = engine();
        let prob = problem();
        let mut rng = Rng::new(7);
        let logits0: Vec<f32> =
            (0..32 * 36).map(|_| rng.normal_ms(0.0, 0.01) as f32).collect();
        let (p_opt, trace) = eng.optimize(&prob, &logits0).unwrap();
        assert_eq!(p_opt.len(), 32 * 36);
        assert_eq!(trace.len(), eng.meta.opt_steps);
        // The returned placement is the best iterate: re-score it natively
        // and check it beats the first step's cost.
        let mut b = CandidateBatch::zeroed(eng.meta, eng.meta.batch_small);
        let rows: Vec<Vec<f64>> = (0..32)
            .map(|i| p_opt[i * 36..(i + 1) * 36].iter().map(|&x| x as f64).collect())
            .collect();
        b.push(&rows);
        let best = crate::runtime::native::score_batch(&prob, &b)[0].total;
        assert!(
            best <= trace[0] * 1.01,
            "optimizer best ({best}) worse than first step ({})",
            trace[0]
        );
        // Live rows are distributions; padding rows are ~zero.
        for i in 0..prob.vms {
            let row: f32 = p_opt[i * 36..(i + 1) * 36].iter().sum();
            assert!((row - 1.0).abs() < 1e-3, "row {i} sums to {row}");
        }
        let pad: f32 = p_opt[prob.vms * 36..].iter().sum();
        assert!(pad.abs() < 1e-3);
    }

    #[test]
    fn empty_batch_short_circuits() {
        let eng = engine();
        let prob = problem();
        let b = CandidateBatch::zeroed(eng.meta, eng.meta.batch_small);
        assert!(eng.score(&prob, &b).unwrap().is_empty());
        assert_eq!(eng.scorer_calls.get(), 0);
    }

    #[test]
    fn stub_free_build_smoke() {
        // With the feature on, load_default may or may not find artifacts;
        // either way it must not panic.
        let _ = Engine::load_default();
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_load_fails_and_default_is_none() {
        assert!(Engine::load("/nonexistent").is_err());
        assert!(Engine::load_default().is_none());
    }
}
