//! Per-tick flow accounting over the link graph and the M/M/1-style
//! congestion model.
//!
//! A [`LinkLedger`] charges every flow of one tick — remote-memory
//! traffic from each VM's page placement plus in-flight migration
//! transfers — to the links on its route.  The from-scratch evaluator
//! (`perf_model::evaluate_with_fabric` / `workload_link_demand`) builds
//! one per tick; the incremental evaluator maintains the same per-link
//! sums by subtract-stale/add-fresh and is oracle-tested against this
//! path.  Link utilization `ρ = demand / capacity` then yields a
//! **congestion factor**
//!
//! ```text
//! φ(ρ) = 1 + ρ / (1 − ρ)        for ρ < 0.95
//!        (linear tail above, slope φ'(0.95), so φ stays finite)
//! ```
//!
//! — the M/M/1 sojourn-time inflation (service + queueing over service).
//! `φ(0) = 1` exactly, which is what makes the uncongested fabric
//! reproduce the scalar model bit-for-bit, and `φ` is monotone in load
//! (property-tested).  The perf model stretches cross-server SLIT
//! distances by the mean per-hop `φ` of the flow's route and shrinks the
//! remote bandwidth share by the same factor.

use super::graph::{FabricGraph, LinkId, Route};

/// Utilization beyond which the M/M/1 curve switches to its linear tail
/// (offered load routinely exceeds link capacity in a saturated fabric;
/// the raw hyperbola would explode).
pub const RHO_CLAMP: f64 = 0.95;

/// M/M/1-style congestion factor for one link at utilization `rho`:
/// relative time-in-system inflation, exactly 1 at zero load, strictly
/// increasing, finite for any load.
pub fn congestion_factor(rho: f64) -> f64 {
    if rho <= 0.0 {
        return 1.0;
    }
    if rho < RHO_CLAMP {
        return 1.0 + rho / (1.0 - rho);
    }
    // Continue with the tangent at RHO_CLAMP: continuous and monotone.
    let base = 1.0 + RHO_CLAMP / (1.0 - RHO_CLAMP);
    let slope = 1.0 / ((1.0 - RHO_CLAMP) * (1.0 - RHO_CLAMP));
    base + (rho - RHO_CLAMP) * slope
}

/// Per-link demand accumulator for one tick.
#[derive(Debug, Clone)]
pub struct LinkLedger {
    demand: Vec<f64>,
}

impl LinkLedger {
    /// Zeroed ledger over `num_links` links.
    pub fn new(num_links: usize) -> Self {
        Self { demand: vec![0.0; num_links] }
    }

    /// Reset every link's accumulated demand to zero.
    pub fn clear(&mut self) {
        self.demand.iter_mut().for_each(|d| *d = 0.0);
    }

    /// Charge one flow of `gbs` to every link on its route.
    pub fn charge_route(&mut self, route: &Route, gbs: f64) {
        for l in &route.links {
            self.demand[l.0] += gbs;
        }
    }

    /// Charge `gbs` to one specific link.
    pub fn charge_link(&mut self, link: LinkId, gbs: f64) {
        self.demand[link.0] += gbs;
    }

    /// Accumulated demand on `link`, GB/s.
    pub fn demand(&self, link: LinkId) -> f64 {
        self.demand[link.0]
    }

    /// Per-link demand vector, indexed by `LinkId`.
    pub fn demands(&self) -> &[f64] {
        &self.demand
    }

    /// Consume the ledger, yielding the per-link demand vector.
    pub fn into_demands(self) -> Vec<f64> {
        self.demand
    }

    /// Total charge across all links (= Σ per-flow demand × route hops).
    pub fn total_demand(&self) -> f64 {
        self.demand.iter().sum()
    }

    /// `ρ` of one link under the graph's current capacities.  A downed
    /// link (capacity 0) with pending demand reports saturated.
    pub fn utilization(&self, graph: &FabricGraph, link: LinkId) -> f64 {
        rho(self.demand[link.0], graph.capacity_gbs(link))
    }

    /// Congestion factor per link (allocates; the per-tick evaluators
    /// keep their own scratch instead).
    pub fn phi_all(&self, graph: &FabricGraph) -> Vec<f64> {
        let mut out = vec![1.0; self.demand.len()];
        self.phi_into(graph, &mut out);
        out
    }

    /// [`Self::phi_all`] into caller-owned scratch — the no-allocation
    /// form the per-tick evaluators use.
    pub fn phi_into(&self, graph: &FabricGraph, out: &mut [f64]) {
        assert_eq!(out.len(), self.demand.len(), "phi scratch sized to the link count");
        for (l, o) in out.iter_mut().enumerate() {
            *o = congestion_factor(self.utilization(graph, LinkId(l)));
        }
    }

    /// Fold another ledger's charges into this one — the deterministic
    /// reduction step for per-zone partial ledgers (always merge in fixed
    /// zone order: float addition is not associative).
    pub fn merge_from(&mut self, other: &LinkLedger) {
        assert_eq!(other.demand.len(), self.demand.len(), "merging ledgers over one graph");
        for (d, o) in self.demand.iter_mut().zip(other.demand.iter()) {
            *d += o;
        }
    }
}

/// Utilization with a defined answer for zero-capacity (downed) links.
pub fn rho(demand: f64, capacity: f64) -> f64 {
    if capacity > 0.0 {
        demand / capacity
    } else if demand > 0.0 {
        1e6 // fully saturated; congestion_factor's linear tail stays finite
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{ServerId, TopologySpec};

    #[test]
    fn congestion_factor_anchors() {
        assert_eq!(congestion_factor(0.0), 1.0);
        assert_eq!(congestion_factor(-1.0), 1.0);
        assert!((congestion_factor(0.5) - 2.0).abs() < 1e-12, "1 + 0.5/0.5");
        // Continuous at the clamp.
        let below = congestion_factor(RHO_CLAMP - 1e-9);
        let above = congestion_factor(RHO_CLAMP + 1e-9);
        assert!((above - below).abs() < 1e-5);
        assert!(congestion_factor(1e6).is_finite());
    }

    #[test]
    fn charges_accumulate_along_routes() {
        let g = FabricGraph::build(&TopologySpec::paper());
        let mut ledger = LinkLedger::new(g.num_links());
        let route = g.route(ServerId(0), ServerId(4)); // 2 hops
        assert_eq!(route.hops(), 2);
        ledger.charge_route(route, 1.5);
        assert!((ledger.total_demand() - 3.0).abs() < 1e-12, "1.5 GB/s x 2 links");
        for l in &route.links {
            assert!((ledger.demand(*l) - 1.5).abs() < 1e-12);
        }
        ledger.clear();
        assert_eq!(ledger.total_demand(), 0.0);
    }

    #[test]
    fn utilization_tracks_capacity() {
        let mut g = FabricGraph::build(&TopologySpec::paper());
        let mut ledger = LinkLedger::new(g.num_links());
        let l = g.link_between(ServerId(0), ServerId(1)).unwrap();
        ledger.charge_link(l, 1.0);
        assert!((ledger.utilization(&g, l) - 0.5).abs() < 1e-12, "1 of 2 GB/s");
        g.set_uniform_scale(0.5);
        assert!((ledger.utilization(&g, l) - 1.0).abs() < 1e-12);
        let phis = ledger.phi_all(&g);
        assert!(phis[l.0] > 1.0);
        assert!(phis.iter().all(|p| *p >= 1.0 && p.is_finite()));
    }

    #[test]
    fn zone_partial_ledgers_merge_to_the_serial_charge() {
        let g = FabricGraph::build(&TopologySpec::paper());
        // Serial: every ordered pair charged once.
        let mut serial = LinkLedger::new(g.num_links());
        for a in 0..6 {
            for b in 0..6 {
                if a != b {
                    serial.charge_route(g.route(ServerId(a), ServerId(b)), 0.25 * (a + 1) as f64);
                }
            }
        }
        // Zoned: source servers split into two bands, merged in zone order.
        let mut merged = LinkLedger::new(g.num_links());
        for band in [0..3, 3..6] {
            let mut partial = LinkLedger::new(g.num_links());
            for a in band {
                for b in 0..6 {
                    if a != b {
                        partial
                            .charge_route(g.route(ServerId(a), ServerId(b)), 0.25 * (a + 1) as f64);
                    }
                }
            }
            merged.merge_from(&partial);
        }
        for l in 0..g.num_links() {
            assert_eq!(merged.demand(LinkId(l)), serial.demand(LinkId(l)));
        }
        // phi_into matches phi_all on the same graph.
        let mut scratch = vec![0.0; g.num_links()];
        merged.phi_into(&g, &mut scratch);
        assert_eq!(scratch, merged.phi_all(&g));
    }

    #[test]
    fn downed_link_with_demand_is_saturated() {
        assert_eq!(rho(0.0, 0.0), 0.0);
        assert!(rho(1.0, 0.0) > 1e5);
    }
}
