//! The routed link graph of the inter-server interconnect.
//!
//! [`FabricGraph`] replaces the scalar fabric view (`fabric_link_bw_gbs /
//! server_hops`) with an explicit set of **directed links** wired from the
//! topology's torus: each server owns one link per direction to each torus
//! neighbour, every link with its own capacity and up/down state.  Routes
//! between every server pair are precomputed by BFS over the live links
//! (deterministic: neighbours explored in ascending destination order) and
//! recomputed automatically when a link goes down or comes back — the
//! re-routing behind the `FabricLinkDown`/`FabricLinkRestored` scenario
//! events.
//!
//! **Parity contract**: with every link up at nominal scale, routes have
//! exactly `Torus::hops` links and [`FabricGraph::route_bw_gbs`] equals
//! the scalar model's `fabric_link_bw_gbs / hops` (store-and-forward per
//! hop) — property-tested in `tests/properties.rs`, which is what keeps
//! every pre-fabric result reproducible.

use std::collections::{BTreeMap, VecDeque};

use anyhow::{bail, Result};

use crate::topology::{torus::Torus, ServerId, TopologySpec};

/// Index of one directed link.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LinkId(pub usize);

/// One directed inter-server link.
#[derive(Debug, Clone)]
pub struct Link {
    /// Source server.
    pub from: ServerId,
    /// Destination server.
    pub to: ServerId,
    /// Nominal per-direction capacity, GB/s.
    pub base_cap_gbs: f64,
}

/// A precomputed shortest path between two servers: the links crossed, in
/// order.  Empty for `a == a` (and for unreachable pairs, which the
/// simulator's disconnect guard prevents).
#[derive(Debug, Clone, Default)]
pub struct Route {
    /// Links crossed, source-side first.
    pub links: Vec<LinkId>,
}

impl Route {
    /// Hop count (= number of links crossed).
    pub fn hops(&self) -> usize {
        self.links.len()
    }
}

/// The link-graph model of the disaggregation fabric.
#[derive(Debug, Clone)]
pub struct FabricGraph {
    servers: usize,
    links: Vec<Link>,
    /// Per-link up/down state (scenario link failures).
    up: Vec<bool>,
    /// Per-server crash state: a down server takes every attached link out
    /// of service atomically (orthogonal to individual link failures, so a
    /// recovering server re-exposes exactly the per-link state it had).
    server_down: Vec<bool>,
    /// Uniform health multiplier in (0, 1] (`degrade_fabric` semantics:
    /// one scale across all links).
    uniform_scale: f64,
    /// Outgoing links per server, ascending destination (BFS determinism).
    adj: Vec<Vec<LinkId>>,
    /// `(from, to)` server pair -> link.
    index: BTreeMap<(usize, usize), LinkId>,
    /// `routes[a * servers + b]` — shortest live path a -> b.
    routes: Vec<Route>,
    /// Times the routing table was recomputed after a link event.
    pub reroutes: u64,
}

impl FabricGraph {
    /// Wire the graph from the topology's torus: one directed link per
    /// neighbour direction per server, at `fabric_link_bw_gbs` each.
    pub fn build(spec: &TopologySpec) -> Self {
        let torus = Torus::new(spec.torus.0, spec.torus.1);
        let servers = spec.servers;
        let mut links = Vec::new();
        let mut adj: Vec<Vec<LinkId>> = vec![Vec::new(); servers];
        let mut index = BTreeMap::new();
        for s in 0..servers {
            // `Torus::neighbors` is sorted and de-duplicated.
            for n in torus.neighbors(s) {
                let id = LinkId(links.len());
                links.push(Link {
                    from: ServerId(s),
                    to: ServerId(n),
                    base_cap_gbs: spec.fabric_link_bw_gbs,
                });
                adj[s].push(id);
                index.insert((s, n), id);
            }
        }
        let up = vec![true; links.len()];
        let mut g = Self {
            servers,
            links,
            up,
            server_down: vec![false; servers],
            uniform_scale: 1.0,
            adj,
            index,
            routes: Vec::new(),
            reroutes: 0,
        };
        g.compute_routes();
        g
    }

    /// Servers in the graph.
    pub fn num_servers(&self) -> usize {
        self.servers
    }

    /// Directed links in the graph.
    pub fn num_links(&self) -> usize {
        self.links.len()
    }

    /// The link with index `id`.
    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0]
    }

    /// All links with their indices, ascending.
    pub fn links(&self) -> impl Iterator<Item = (LinkId, &Link)> {
        self.links.iter().enumerate().map(|(i, l)| (LinkId(i), l))
    }

    /// Is the link up (not failed by a scenario event)?
    pub fn is_up(&self, id: LinkId) -> bool {
        self.up[id.0]
    }

    /// Is the server crashed (all its links out of service)?
    pub fn is_server_down(&self, s: ServerId) -> bool {
        self.server_down[s.0]
    }

    /// Is the link in service: individually up *and* neither endpoint
    /// server crashed.
    fn link_live(&self, id: LinkId) -> bool {
        let l = &self.links[id.0];
        self.up[id.0] && !self.server_down[l.from.0] && !self.server_down[l.to.0]
    }

    /// Current uniform health multiplier in (0, 1].
    pub fn uniform_scale(&self) -> f64 {
        self.uniform_scale
    }

    /// Effective capacity of a link, GB/s (0 when down or when either
    /// endpoint server crashed).
    pub fn capacity_gbs(&self, id: LinkId) -> f64 {
        if self.link_live(id) {
            self.links[id.0].base_cap_gbs * self.uniform_scale
        } else {
            0.0
        }
    }

    /// The direct link `a -> b`, if the torus wires one.
    pub fn link_between(&self, a: ServerId, b: ServerId) -> Option<LinkId> {
        self.index.get(&(a.0, b.0)).copied()
    }

    /// Row-major index of the `(a, b)` route in the route table.
    pub fn route_index(&self, a: ServerId, b: ServerId) -> usize {
        a.0 * self.servers + b.0
    }

    /// Current shortest live path `a -> b`.
    pub fn route(&self, a: ServerId, b: ServerId) -> &Route {
        &self.routes[self.route_index(a, b)]
    }

    /// Route by precomputed index (the incremental evaluator's cached key).
    pub fn route_at(&self, idx: usize) -> &Route {
        &self.routes[idx]
    }

    /// Live hop count `a -> b` (0 for `a == a`; may exceed the torus
    /// minimum while links are down).
    pub fn hops(&self, a: ServerId, b: ServerId) -> usize {
        self.route(a, b).hops()
    }

    /// Achievable bandwidth of the `a -> b` route, GB/s: the narrowest
    /// link divided by the hop count (store-and-forward per hop — exactly
    /// the scalar model's `fabric_link_bw_gbs / server_hops` on a healthy
    /// uniform fabric).  `INFINITY` for `a == a` (intra-server transfers
    /// never touch the fabric); 0 when no live route exists.
    pub fn route_bw_gbs(&self, a: ServerId, b: ServerId) -> f64 {
        if a == b {
            return f64::INFINITY;
        }
        let route = self.route(a, b);
        if route.links.is_empty() {
            return 0.0;
        }
        let min_cap = route
            .links
            .iter()
            .map(|l| self.capacity_gbs(*l))
            .fold(f64::INFINITY, f64::min);
        min_cap / route.links.len() as f64
    }

    /// Uniform fabric degradation (`Simulator::degrade_fabric`): one scale
    /// across every link.  No re-routing — relative link order is
    /// unchanged.
    pub fn set_uniform_scale(&mut self, scale: f64) {
        self.uniform_scale = scale;
    }

    /// Links currently down, as `(from, to)` server pairs (each failed
    /// pair reported once, in the `from < to` direction).
    pub fn down_links(&self) -> Vec<(ServerId, ServerId)> {
        self.links
            .iter()
            .enumerate()
            .filter(|(i, l)| !self.up[*i] && l.from.0 < l.to.0)
            .map(|(_, l)| (l.from, l.to))
            .collect()
    }

    /// Take the `a <-> b` link pair down (both directions) and re-route.
    /// Refuses when no such link exists, when it is already down, or when
    /// removing it would partition the fabric (a partitioned fabric has no
    /// well-defined remote bandwidth; mirrors the "cannot drain the last
    /// server" guard).
    pub fn set_link_down(&mut self, a: ServerId, b: ServerId) -> Result<()> {
        let fwd = self
            .link_between(a, b)
            .ok_or_else(|| anyhow::anyhow!("no fabric link s{} -> s{}", a.0, b.0))?;
        let rev = self
            .link_between(b, a)
            .ok_or_else(|| anyhow::anyhow!("no fabric link s{} -> s{}", b.0, a.0))?;
        if !self.up[fwd.0] {
            bail!("fabric link s{} <-> s{} is already down", a.0, b.0);
        }
        self.up[fwd.0] = false;
        self.up[rev.0] = false;
        if !self.is_connected() {
            self.up[fwd.0] = true;
            self.up[rev.0] = true;
            bail!("taking down s{} <-> s{} would partition the fabric", a.0, b.0);
        }
        self.compute_routes();
        self.reroutes += 1;
        Ok(())
    }

    /// Bring a failed `a <-> b` link pair back and re-route.
    pub fn restore_link(&mut self, a: ServerId, b: ServerId) -> Result<()> {
        let fwd = self
            .link_between(a, b)
            .ok_or_else(|| anyhow::anyhow!("no fabric link s{} -> s{}", a.0, b.0))?;
        let rev = self
            .link_between(b, a)
            .ok_or_else(|| anyhow::anyhow!("no fabric link s{} -> s{}", b.0, a.0))?;
        if self.up[fwd.0] {
            bail!("fabric link s{} <-> s{} is not down", a.0, b.0);
        }
        self.up[fwd.0] = true;
        self.up[rev.0] = true;
        self.compute_routes();
        self.reroutes += 1;
        Ok(())
    }

    /// Take a server down: every attached link leaves service atomically
    /// (one re-route, not one per link).  Refuses when the server is
    /// already down, when it is the last live server, or when its loss
    /// would partition the *surviving* live servers (mirrors the
    /// `set_link_down` partition guard).
    pub fn set_server_down(&mut self, s: ServerId) -> Result<()> {
        if s.0 >= self.servers {
            bail!("no such server s{}", s.0);
        }
        if self.server_down[s.0] {
            bail!("server s{} is already down", s.0);
        }
        if self.server_down.iter().filter(|d| !**d).count() <= 1 {
            bail!("cannot take down the last live server s{}", s.0);
        }
        self.server_down[s.0] = true;
        if !self.is_connected() {
            self.server_down[s.0] = false;
            bail!("taking down s{} would partition the surviving fabric", s.0);
        }
        self.compute_routes();
        self.reroutes += 1;
        Ok(())
    }

    /// Bring a crashed server back: its links return to their individual
    /// `up` states and routes are recomputed.
    pub fn set_server_up(&mut self, s: ServerId) -> Result<()> {
        if s.0 >= self.servers {
            bail!("no such server s{}", s.0);
        }
        if !self.server_down[s.0] {
            bail!("server s{} is not down", s.0);
        }
        self.server_down[s.0] = false;
        self.compute_routes();
        self.reroutes += 1;
        Ok(())
    }

    /// Is the live-link graph still one component over the live servers?
    /// (Crashed servers are excluded: the guard protects the *survivors*'
    /// mutual reachability.)
    fn is_connected(&self) -> bool {
        let live: Vec<usize> =
            (0..self.servers).filter(|s| !self.server_down[*s]).collect();
        if live.len() <= 1 {
            return true;
        }
        let mut seen = vec![false; self.servers];
        seen[live[0]] = true;
        let mut queue = VecDeque::from([live[0]]);
        let mut count = 1usize;
        while let Some(u) = queue.pop_front() {
            for lid in &self.adj[u] {
                if !self.link_live(*lid) {
                    continue;
                }
                let v = self.links[lid.0].to.0;
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == live.len()
    }

    /// BFS shortest paths over the live links from every server
    /// (deterministic parent selection: first discovery in ascending
    /// destination order).
    fn compute_routes(&mut self) {
        let s = self.servers;
        let mut routes = vec![Route::default(); s * s];
        for src in 0..s {
            let mut prev: Vec<Option<LinkId>> = vec![None; s];
            let mut seen = vec![false; s];
            seen[src] = true;
            let mut queue = VecDeque::from([src]);
            while let Some(u) = queue.pop_front() {
                for lid in &self.adj[u] {
                    if !self.link_live(*lid) {
                        continue;
                    }
                    let v = self.links[lid.0].to.0;
                    if !seen[v] {
                        seen[v] = true;
                        prev[v] = Some(*lid);
                        queue.push_back(v);
                    }
                }
            }
            for dst in 0..s {
                if dst == src || !seen[dst] {
                    continue;
                }
                let mut path = Vec::new();
                let mut cur = dst;
                while cur != src {
                    let lid = prev[cur].expect("seen node has a parent link");
                    path.push(lid);
                    cur = self.links[lid.0].from.0;
                }
                path.reverse();
                routes[src * s + dst] = Route { links: path };
            }
        }
        self.routes = routes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_graph() -> FabricGraph {
        FabricGraph::build(&TopologySpec::paper())
    }

    #[test]
    fn paper_wiring_matches_torus() {
        let g = paper_graph();
        let torus = Torus::new(3, 2);
        assert_eq!(g.num_servers(), 6);
        // One directed link per neighbour direction.
        let expect: usize = (0..6).map(|s| torus.neighbors(s).len()).sum();
        assert_eq!(g.num_links(), expect);
        for s in 0..6 {
            for n in torus.neighbors(s) {
                assert!(g.link_between(ServerId(s), ServerId(n)).is_some());
            }
        }
    }

    #[test]
    fn routes_match_torus_hops_when_healthy() {
        let g = paper_graph();
        let torus = Torus::new(3, 2);
        for a in 0..6 {
            for b in 0..6 {
                assert_eq!(
                    g.hops(ServerId(a), ServerId(b)),
                    torus.hops(a, b),
                    "route {a}->{b}"
                );
            }
        }
    }

    #[test]
    fn route_links_are_contiguous() {
        let g = paper_graph();
        for a in 0..6 {
            for b in 0..6 {
                let route = g.route(ServerId(a), ServerId(b));
                let mut at = a;
                for lid in &route.links {
                    let l = g.link(*lid);
                    assert_eq!(l.from.0, at, "route {a}->{b} breaks at {at}");
                    at = l.to.0;
                }
                if a != b {
                    assert_eq!(at, b, "route {a}->{b} ends at {at}");
                }
            }
        }
    }

    #[test]
    fn route_bw_reproduces_scalar_model() {
        let g = paper_graph();
        let spec = TopologySpec::paper();
        let torus = Torus::new(3, 2);
        for a in 0..6 {
            for b in 0..6 {
                if a == b {
                    continue;
                }
                let want = spec.fabric_link_bw_gbs / torus.hops(a, b) as f64;
                let got = g.route_bw_gbs(ServerId(a), ServerId(b));
                assert!((got - want).abs() < 1e-12, "{a}->{b}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn uniform_scale_shrinks_capacity_without_rerouting() {
        let mut g = paper_graph();
        let before = g.hops(ServerId(0), ServerId(4));
        g.set_uniform_scale(0.25);
        assert_eq!(g.hops(ServerId(0), ServerId(4)), before);
        let bw = g.route_bw_gbs(ServerId(0), ServerId(1));
        assert!((bw - 2.0 * 0.25).abs() < 1e-12, "bw {bw}");
        assert_eq!(g.reroutes, 0);
    }

    #[test]
    fn link_down_reroutes_and_restore_recovers() {
        let mut g = paper_graph();
        assert_eq!(g.hops(ServerId(0), ServerId(1)), 1);
        g.set_link_down(ServerId(0), ServerId(1)).unwrap();
        assert!(!g.is_up(g.link_between(ServerId(0), ServerId(1)).unwrap()));
        let detour = g.hops(ServerId(0), ServerId(1));
        assert!(detour >= 2, "downed direct link must force a detour: {detour}");
        // The detour never crosses the dead link.
        for lid in &g.route(ServerId(0), ServerId(1)).links {
            assert!(g.is_up(*lid));
        }
        assert_eq!(g.down_links(), vec![(ServerId(0), ServerId(1))]);
        g.restore_link(ServerId(0), ServerId(1)).unwrap();
        assert_eq!(g.hops(ServerId(0), ServerId(1)), 1);
        assert_eq!(g.reroutes, 2);
    }

    #[test]
    fn link_event_validation() {
        let mut g = paper_graph();
        // Servers 0 and 4 are not torus neighbours on the 3x2 grid.
        assert_eq!(Torus::new(3, 2).hops(0, 4), 2);
        assert!(g.set_link_down(ServerId(0), ServerId(4)).is_err());
        assert!(g.restore_link(ServerId(0), ServerId(1)).is_err(), "not down");
        g.set_link_down(ServerId(0), ServerId(1)).unwrap();
        assert!(g.set_link_down(ServerId(0), ServerId(1)).is_err(), "double down");
    }

    #[test]
    fn server_down_kills_all_attached_links_atomically() {
        let mut g = paper_graph();
        g.set_server_down(ServerId(1)).unwrap();
        assert!(g.is_server_down(ServerId(1)));
        assert_eq!(g.reroutes, 1, "one atomic re-route, not one per link");
        for (lid, l) in g.links() {
            if l.from.0 == 1 || l.to.0 == 1 {
                assert_eq!(g.capacity_gbs(lid), 0.0, "link touching s1 still live");
                // The per-link state is untouched: the outage is the server.
                assert!(g.is_up(lid));
            }
        }
        // No surviving route crosses the crashed server.
        for a in 0..6 {
            for b in 0..6 {
                if a == 1 || b == 1 || a == b {
                    continue;
                }
                let route = g.route(ServerId(a), ServerId(b));
                assert!(!route.links.is_empty(), "survivors {a}->{b} unreachable");
                for lid in &route.links {
                    let l = g.link(*lid);
                    assert!(l.from.0 != 1 && l.to.0 != 1, "route {a}->{b} crosses s1");
                }
            }
        }
        // Routes to/from the crashed server are gone.
        assert_eq!(g.route_bw_gbs(ServerId(0), ServerId(1)), 0.0);
        assert_eq!(g.route_bw_gbs(ServerId(1), ServerId(0)), 0.0);
    }

    #[test]
    fn server_up_restores_routes_and_preserves_link_state() {
        let mut g = paper_graph();
        g.set_link_down(ServerId(0), ServerId(1)).unwrap();
        g.set_server_down(ServerId(1)).unwrap();
        g.set_server_up(ServerId(1)).unwrap();
        assert!(!g.is_server_down(ServerId(1)));
        // The individually failed link stays failed across the crash.
        assert!(!g.is_up(g.link_between(ServerId(0), ServerId(1)).unwrap()));
        assert!(g.hops(ServerId(0), ServerId(1)) >= 2);
        g.restore_link(ServerId(0), ServerId(1)).unwrap();
        assert_eq!(g.hops(ServerId(0), ServerId(1)), 1);
    }

    #[test]
    fn server_down_validation_and_partition_guard() {
        // Ring of 4: 0-1-2-3-0.  Losing s1 keeps survivors connected via
        // 0-3-2; then losing s3 would strand s0 from s2.
        let spec = TopologySpec { servers: 4, torus: (4, 1), ..TopologySpec::paper() };
        let mut g = FabricGraph::build(&spec);
        assert!(g.set_server_down(ServerId(9)).is_err(), "out of range");
        assert!(g.set_server_up(ServerId(0)).is_err(), "not down");
        g.set_server_down(ServerId(1)).unwrap();
        assert!(g.set_server_down(ServerId(1)).is_err(), "double down");
        let reroutes = g.reroutes;
        assert!(g.set_server_down(ServerId(3)).is_err(), "partitions survivors");
        assert!(!g.is_server_down(ServerId(3)), "refused op must not stick");
        assert_eq!(g.reroutes, reroutes, "refused op must not re-route");
        g.set_server_up(ServerId(1)).unwrap();
        assert_eq!(g.hops(ServerId(0), ServerId(1)), 1);
    }

    #[test]
    fn partitioning_link_down_is_refused() {
        // A 2x1 torus has a single (de-duplicated) link pair; removing it
        // would split the fabric.
        let spec = TopologySpec { servers: 2, torus: (2, 1), ..TopologySpec::paper() };
        let mut g = FabricGraph::build(&spec);
        assert!(g.set_link_down(ServerId(0), ServerId(1)).is_err());
        // State untouched by the refused operation.
        assert_eq!(g.hops(ServerId(0), ServerId(1)), 1);
        assert_eq!(g.reroutes, 0);
    }
}
