//! First-class fabric subsystem: a routed link-graph model of the
//! inter-server interconnect with congestion-aware accounting.
//!
//! The disaggregation literature (DaeMon, Maruf & Chowdhury's survey)
//! argues the fabric must be modelled as a **shared, contended resource**,
//! not a scalar: data movement over it dominates application performance
//! and congestion management is a core open problem.  This module
//! provides exactly that:
//!
//! * [`graph::FabricGraph`] — directed links wired from the topology's
//!   torus, per-link capacity/health, precomputed shortest-path
//!   [`graph::Route`]s with automatic re-routing around failed links;
//! * [`ledger::LinkLedger`] — per-tick accounting that charges every flow
//!   (remote-memory traffic, migration transfers) to the links on its
//!   route;
//! * [`ledger::congestion_factor`] — the M/M/1-style inflation the perf
//!   model applies to effective inter-server latency and bandwidth.
//!
//! **Parity**: an uncongested fabric reproduces the pre-fabric scalar
//! model exactly — routes have `Torus::hops` links, route bandwidth is
//! `fabric_link_bw_gbs / hops`, and `φ(0) = 1` leaves distances and
//! bandwidth shares untouched.  The congestion *feedback* into the perf
//! model is therefore opt-in per simulation ([`FabricParams::feedback`],
//! default off), keeping every existing scenario bit-identical while the
//! `fabric` experiment and the `degraded-link` scenario turn it on.

pub mod graph;
pub mod ledger;

pub use graph::{FabricGraph, Link, LinkId, Route};
pub use ledger::{congestion_factor, rho, LinkLedger, RHO_CLAMP};

/// Fabric-model knobs carried by `SimConfig`.
#[derive(Debug, Clone, Default)]
pub struct FabricParams {
    /// Feed link congestion back into the performance model (latency
    /// stretch + remote-bandwidth shrink) and draw migration budgets from
    /// residual rather than nominal route capacity.  Off by default: the
    /// uncongested fabric then reproduces the scalar model exactly.
    pub feedback: bool,
}

/// Fraction of a link's capacity migrations may always use, however
/// congested the workload traffic is (feedback mode): pages must keep
/// moving or a congested system can never heal itself.
pub const MIGRATION_RESIDUAL_FLOOR: f64 = 0.05;

/// Residual capacity factor of one link for migration traffic: what the
/// workload's demand leaves over, floored at
/// [`MIGRATION_RESIDUAL_FLOOR`].
pub fn migration_residual(workload_gbs: f64, capacity_gbs: f64) -> f64 {
    if capacity_gbs <= 0.0 {
        return 1.0; // down links carry no routes; factor is irrelevant
    }
    (1.0 - workload_gbs / capacity_gbs).max(MIGRATION_RESIDUAL_FLOOR)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_shrinks_with_load_and_floors() {
        assert_eq!(migration_residual(0.0, 2.0), 1.0);
        assert!((migration_residual(1.0, 2.0) - 0.5).abs() < 1e-12);
        assert_eq!(migration_residual(100.0, 2.0), MIGRATION_RESIDUAL_FLOOR);
        assert_eq!(migration_residual(1.0, 0.0), 1.0);
    }

    #[test]
    fn feedback_defaults_off() {
        assert!(!FabricParams::default().feedback);
    }
}
