#!/usr/bin/env python3
"""Bench regression gate for CI.

Compares a fresh `BENCH_hotpath.json` (written by
`cargo bench --bench bench_hotpath -- --quick`) against the committed
baseline and fails on a >threshold slowdown of any benchmark present in
both files.  All recorded metrics are seconds (lower is better), so a
single rule covers scorer latencies and sim seconds-per-tick (the
inverse of ticks/sec) alike.

Exit codes: 0 = pass (or bootstrap: no baseline to compare against),
1 = regression beyond threshold, 2 = usage/parse error.

Override: set BENCH_OVERRIDE=true (the CI workflow sets it when the PR
carries the `bench-regression-override` label) to report regressions
without failing the job — for intentional trade-offs, with the artifact
keeping the new numbers on record.
"""

import argparse
import json
import os
import sys


def load(path):
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    out = {}
    for b in data.get("benchmarks", []):
        # min_s is the most stable statistic on shared CI runners.
        out[b["name"]] = float(b.get("min_s", b.get("mean_s", 0.0)))
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--current", required=True, help="freshly measured JSON")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.25,
        help="allowed fractional slowdown (default 0.25 = 25%%)",
    )
    ap.add_argument(
        "--require",
        default="",
        help="comma-separated benchmark names that must exist in the "
        "current results — guards against a rename/removal silently "
        "disarming the gate for a key metric",
    )
    args = ap.parse_args()

    required = [k for k in (s.strip() for s in args.require.split(",")) if k]
    if required:
        try:
            cur_names = set(load(args.current))
        except (OSError, ValueError, KeyError) as e:
            print(f"[bench-gate] cannot parse current results: {e}")
            return 2
        missing = sorted(k for k in required if k not in cur_names)
        if missing:
            print(f"[bench-gate] required benchmarks missing from current "
                  f"results: {', '.join(missing)}")
            return 1

    if not os.path.exists(args.baseline):
        print(
            f"[bench-gate] no baseline at {args.baseline} — bootstrap run. "
            "Commit the uploaded BENCH_hotpath.json artifact as the baseline "
            "to arm the gate."
        )
        return 0
    try:
        base = load(args.baseline)
        cur = load(args.current)
    except (OSError, ValueError, KeyError) as e:
        print(f"[bench-gate] cannot parse inputs: {e}")
        return 2

    common = sorted(set(base) & set(cur))
    if not common:
        print("[bench-gate] no common benchmark names — nothing to compare.")
        return 0

    override = os.environ.get("BENCH_OVERRIDE", "").lower() in ("1", "true", "yes")
    regressions = []
    print(f"[bench-gate] comparing {len(common)} benchmarks "
          f"(threshold {args.threshold:.0%}, min_s, lower is better)")
    for name in common:
        b, c = base[name], cur[name]
        if b <= 0.0:
            continue
        ratio = c / b - 1.0
        flag = ""
        if ratio > args.threshold:
            regressions.append((name, ratio))
            flag = "  << REGRESSION"
        print(f"  {name:<44} base={b:.6g}s cur={c:.6g}s delta={ratio:+.1%}{flag}")

    if regressions:
        worst = max(r for _, r in regressions)
        print(f"[bench-gate] {len(regressions)} regression(s), worst {worst:+.1%}")
        if override:
            print("[bench-gate] BENCH_OVERRIDE set (bench-regression-override "
                  "label) — reporting only, not failing.")
            return 0
        print("[bench-gate] FAIL. If intentional, apply the "
              "`bench-regression-override` label and re-run, then commit the "
              "new BENCH_hotpath.json as the baseline.")
        return 1
    print("[bench-gate] OK — no benchmark regressed beyond the threshold.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
